"""Streaming session API (ISSUE 5): incremental record-batch execution.

The equivalence contract:

* **one batch ≡ one shot** — opening a session, feeding the whole stream
  as a single :class:`RecordBatch` and closing is *bit-identical* to
  ``Engine.run`` (same ``TopologyReport.to_dict()``) for all six schemes
  on both engines — ``run`` literally is open/advance/feed/close.
* **many batches ≈ one shot** — cutting the stream into several feeds is
  exact for the stateless/sequentially-exact schemes (SG/FG/PKG: carried
  FIFO backlog + carried grouper counters reproduce the same routing and
  finish times up to float association) and bounded-drift for the
  epoch-paced schemes (DC/WC/FISH: feed boundaries shift epoch sub-chunk
  boundaries, like any other segmentation change — DESIGN.md §6 bands).
* **time addressing** — an ``at_time`` event lands on the same segment cut
  as the equivalent index event.
* **payloads** — a ``WindowOp(value="payload")`` aggregates the stream's
  real ``values`` column; merged windows match a direct NumPy aggregation.
"""

import numpy as np
import pytest

from repro.core import CapacityEvent, MembershipEvent, at_time
from repro.data.synthetic import record_batches, zipf_time_evolving
from repro.state import KeyedStateManager, WindowOp, direct_aggregate
from repro.topology import (Edge, RecordBatch, ScopedEvent,
                            ServingTopologyEngine, SimulatorEngine, Source,
                            Stage, Topology, WindowOp as TopoWindowOp,
                            config_for, hashed_fanout)

from repro.analysis.contracts import (DRIFT_SCHEMES, EXACT_SCHEMES,
                                      SCHEMES)


@pytest.fixture(scope="module")
def keys():
    return zipf_time_evolving(6_000, num_keys=600, z=1.4, seed=0)


def _single(scheme, workers=8, cost=None, operator=None):
    return Topology(
        name=f"s-{scheme}",
        stages=(Stage("worker", workers, cost=cost, operator=operator),),
        edges=(Edge("source", "worker", config_for(scheme)),),
    )


def _word_count(scheme, cost=None):
    return Topology(
        name="wc",
        stages=(Stage("split", 5, cost=cost,
                      transform=hashed_fanout(3, 300)),
                Stage("count", 7, cost=cost)),
        edges=(Edge("source", "split", config_for("sg")),
               Edge("split", "count", config_for(scheme))),
    )


def _session_run(engine, topo, source, events=(), feeds=1):
    session = engine.open(topo, arrival_rate=source.arrival_rate)
    if events:
        session.advance(events)
    n = int(source.keys.shape[0])
    for batch in source.iter_batches(batch_size=-(-n // feeds)):
        session.feed(batch)
    return session.close()


# ---------------------------------------------------------------------------
# one-batch session == run(), bit-identical (acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", SCHEMES)
def test_one_batch_session_bit_identical_to_run_simulator(scheme, keys):
    topo = _word_count(scheme)
    src = Source(keys, arrival_rate=2e4)
    n_count = keys.shape[0] * 3
    events = [ScopedEvent("count", MembershipEvent(at=n_count // 2,
                                                   workers=tuple(range(6)))),
              ScopedEvent("count", CapacityEvent(at=2 * n_count // 3,
                                                 capacities={0: 4e-3}))]
    eng = SimulatorEngine()
    assert (_session_run(eng, topo, src, events).to_dict()
            == eng.run(topo, src, events).to_dict())


@pytest.mark.parametrize("scheme", SCHEMES)
def test_one_batch_session_bit_identical_to_run_serving(scheme, keys):
    topo = _word_count(scheme)
    src = Source(keys, arrival_rate=2e4)
    events = [ScopedEvent("count", MembershipEvent(at=48,
                                                   workers=tuple(range(6))))]
    eng = ServingTopologyEngine(max_requests=64)
    assert (_session_run(eng, topo, src, events).to_dict()
            == eng.run(topo, src, events).to_dict())


def test_one_batch_session_bit_identical_reference_mode(keys):
    topo = _word_count("fish")
    src = Source(keys, arrival_rate=2e4)
    eng = SimulatorEngine(mode="reference")
    assert (_session_run(eng, topo, src).to_dict()
            == eng.run(topo, src).to_dict())


def test_one_batch_session_bit_identical_with_operator_state(keys):
    op = TopoWindowOp(agg="count", size=1_000)
    topo = Topology(name="op", stages=(
        Stage("count", 6, operator=op), Stage("merge", 4)),
        edges=(Edge("source", "count", config_for("fish")),
               Edge("count", "merge", config_for("fg"))))
    src = Source(keys, arrival_rate=2e4)
    events = [ScopedEvent("count", MembershipEvent(at=2_500,
                                                   workers=tuple(range(5))))]
    for eng in (SimulatorEngine(), ServingTopologyEngine(max_requests=64)):
        assert (_session_run(eng, topo, src, events).to_dict()
                == eng.run(topo, src, events).to_dict())


# ---------------------------------------------------------------------------
# multi-batch feeding vs the one-shot oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", EXACT_SCHEMES)
@pytest.mark.parametrize("feeds", (2, 5))
def test_multi_batch_exact_for_sequential_schemes(scheme, feeds, keys):
    # explicit stage costs: capacity defaults are frozen at first feed, so
    # only cost-pinned stages are comparable across batch segmentations
    topo = _word_count(scheme, cost=1e-4)
    src = Source(keys, arrival_rate=2e4)
    one = SimulatorEngine().run(topo, src)
    many = _session_run(SimulatorEngine(), topo, src, feeds=feeds)
    for eo, em in zip(one.edges, many.edges):
        assert em.n_tuples == eo.n_tuples
        assert em.memory_overhead == eo.memory_overhead, eo.edge
        for field, v in eo.row().items():
            assert em.row()[field] == pytest.approx(v, rel=1e-9), \
                (eo.edge, field)
    assert many.e2e_latency_p99 == pytest.approx(one.e2e_latency_p99,
                                                 rel=1e-9)
    assert many.total_time == pytest.approx(one.total_time, rel=1e-9)


@pytest.mark.parametrize("scheme", DRIFT_SCHEMES)
def test_multi_batch_bounded_drift_for_epoch_schemes(scheme, keys):
    topo = _word_count(scheme, cost=1e-4)
    src = Source(keys, arrival_rate=2e4)
    one = SimulatorEngine().run(topo, src)
    many = _session_run(SimulatorEngine(), topo, src, feeds=4)
    for eo, em in zip(one.edges, many.edges):
        assert em.execution_time == pytest.approx(eo.execution_time,
                                                  rel=0.05), eo.edge
        assert em.throughput == pytest.approx(eo.throughput, rel=0.05)
        assert em.memory_overhead == pytest.approx(eo.memory_overhead,
                                                   rel=0.25)
        # load balance must not degrade materially vs the one-shot run
        assert em.imbalance <= eo.imbalance + 0.05, eo.edge
        assert em.latency_p99 <= max(eo.latency_p99 * 10.0, 0.05)
    assert many.total_time == pytest.approx(one.total_time, rel=0.05)


def test_multi_batch_serving_drains_every_feed(keys):
    topo = _word_count("fish")
    src = Source(keys, arrival_rate=2e4)
    eng = ServingTopologyEngine(max_requests=48)
    rep = _session_run(eng, topo, src, feeds=3)
    # each feed is subsampled independently, then fully drained
    assert rep.n_source_tuples == 3 * 48
    assert sum(e.dropped for e in rep.edges) == 0
    assert rep.edge("count").n_tuples == 3 * 48 * 3


def test_event_straddling_feed_boundary_fires_once(keys):
    """A membership event whose index lands inside a later feed fires in
    that feed — and the remap accounting sees exactly one event."""
    topo = _single("fg")
    src = Source(keys, arrival_rate=2e4)
    ev = [ScopedEvent("worker",
                      MembershipEvent(at=4_000, workers=tuple(range(6))))]
    rep = _session_run(SimulatorEngine(), topo, src, ev, feeds=3)
    er = rep.edge("worker")
    assert len(er.remap_events) == 1
    assert er.remap_events[0]["at"] == 4_000  # reported stream-global
    assert 0.0 < er.remap_frac_mean < 0.5


# ---------------------------------------------------------------------------
# time-addressed events
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("feeds", (1, 3))
def test_at_time_lands_on_same_cut_as_index_event(feeds, keys):
    topo = _single("fg")
    src = Source(keys, arrival_rate=2e4)
    j = 4_321
    t = j * (1.0 / 2e4)  # tuple j's timestamp, as the source computes it
    by_index = [ScopedEvent("worker",
                            MembershipEvent(at=j, workers=tuple(range(6))))]
    by_stamp = [ScopedEvent("worker",
                            at_time(MembershipEvent(workers=tuple(range(6))),
                                    t))]
    eng = SimulatorEngine()
    assert (_session_run(eng, topo, src, by_stamp, feeds=feeds).to_dict()
            == _session_run(eng, topo, src, by_index, feeds=feeds).to_dict())


def test_at_time_capacity_event_through_run(keys):
    """``run`` resolves time-addressed events too (one-shot path), and
    capacity events support the same addressing."""
    topo = _single("fish")
    src = Source(keys, arrival_rate=2e4)
    j = 3_000
    slow = {0: 8e-3}
    eng = SimulatorEngine()
    r_idx = eng.run(topo, src,
                    [ScopedEvent("worker", CapacityEvent(at=j,
                                                         capacities=slow))])
    r_t = eng.run(topo, src,
                  [ScopedEvent("worker",
                               at_time(CapacityEvent(capacities=slow),
                                       j * (1.0 / 2e4)))])
    assert r_t.to_dict() == r_idx.to_dict()


def test_at_time_past_stream_end_never_fires(keys):
    topo = _single("fg")
    src = Source(keys, arrival_rate=2e4)
    ev = [ScopedEvent("worker",
                      at_time(MembershipEvent(workers=(0, 1)), 1e9))]
    rep = _session_run(SimulatorEngine(), topo, src, ev, feeds=2)
    assert rep.edge("worker").remap_events == []


# ---------------------------------------------------------------------------
# payload-carrying sources
# ---------------------------------------------------------------------------


def test_payload_sum_matches_numpy_direct_aggregation():
    rng = np.random.default_rng(3)
    n, size = 4_000, 500
    keys = rng.integers(0, 97, n).astype(np.int32)
    values = rng.integers(1, 1_000, n).astype(np.float64)
    op = TopoWindowOp(agg="sum", size=size, value="payload")
    topo = _single("fg", operator=op)
    rep = SimulatorEngine().run(
        topo, Source(keys, arrival_rate=2e4, values=values))
    merged = rep.state["worker"]["merged"]
    for start in range(0, n, size):
        ks = keys[start:start + size].astype(np.int64)
        vs = values[start:start + size].astype(np.int64)
        expect = {}
        for k, v in zip(ks.tolist(), vs.tolist()):
            expect[k] = expect.get(k, 0) + v
        assert merged[start] == expect, start
    # the oracle helper accepts the payload column too
    assert merged == direct_aggregate(keys, op, values=values)


@pytest.mark.parametrize("scheme", ("sg", "fish"))
def test_payload_sum_exact_across_feeds_and_churn(scheme):
    rng = np.random.default_rng(7)
    n = 6_000
    keys = rng.integers(0, 300, n).astype(np.int32)
    values = rng.integers(1, 50, n).astype(np.float64)
    op = TopoWindowOp(agg="sum", size=1_000, value="payload")
    topo = _single(scheme, operator=op)
    src = Source(keys, arrival_rate=2e4, values=values)
    ev = [ScopedEvent("worker",
                      MembershipEvent(at=2_500, workers=tuple(range(7))))]
    rep = _session_run(SimulatorEngine(), topo, src, ev, feeds=4)
    assert (rep.state["worker"]["merged"]
            == direct_aggregate(keys, op, values=values))


def test_payload_op_without_values_column_raises():
    op = TopoWindowOp(agg="sum", size=100, value="payload")
    topo = _single("fg", operator=op)
    with pytest.raises(ValueError, match="payload"):
        SimulatorEngine().run(
            topo, Source(np.arange(500, dtype=np.int32),
                         arrival_rate=1e4))


def test_values_propagate_through_transform_stages():
    """A split stage's emitted tuples inherit the parent payload, so a
    downstream payload-sum operator aggregates fanout copies."""
    n, fanout = 900, 3
    keys = np.arange(n, dtype=np.int32) % 11
    values = np.ones(n, dtype=np.float64) * 5
    op = TopoWindowOp(agg="sum", size=n * fanout, value="payload")
    topo = Topology(
        name="vp",
        stages=(Stage("split", 4, transform=hashed_fanout(fanout, 40)),
                Stage("count", 6, operator=op)),
        edges=(Edge("source", "split", config_for("sg")),
               Edge("split", "count", config_for("fg"))),
    )
    rep = SimulatorEngine().run(
        topo, Source(keys, arrival_rate=1e4, values=values))
    merged = rep.state["count"]["merged"]
    total = sum(v for w in merged.values() for v in w.values())
    assert total == int(values.sum()) * fanout


# ---------------------------------------------------------------------------
# record-batch plumbing and validation
# ---------------------------------------------------------------------------


def test_record_batch_validation():
    with pytest.raises(TypeError, match="integer"):
        RecordBatch(np.array(["a", "b"], dtype=object), np.zeros(2))
    with pytest.raises(ValueError, match="shape"):
        RecordBatch(np.arange(3, dtype=np.int32), np.zeros(2))
    with pytest.raises(ValueError, match="nondecreasing"):
        RecordBatch(np.arange(3, dtype=np.int32),
                    np.array([0.0, 2.0, 1.0]))
    with pytest.raises(ValueError, match="shape"):
        RecordBatch(np.arange(3, dtype=np.int32), np.zeros(3),
                    values=np.zeros(4))
    b = RecordBatch(np.arange(3, dtype=np.int32), np.arange(3) * 0.1,
                    values=np.ones(3))
    assert len(b) == 3
    assert not b.keys.flags.writeable  # frozen columns
    assert not b.values.flags.writeable


def test_source_forms_and_validation(keys):
    with pytest.raises(ValueError, match="exactly one"):
        Source()
    with pytest.raises(ValueError, match="exactly one"):
        Source(keys, batches=iter(()))
    with pytest.raises(TypeError, match="RecordBatch"):
        Source(batches=(np.arange(3),)).iter_batches().__next__()
    # array form splits on the uniform grid and round-trips the stream
    src = Source(keys, arrival_rate=2e4)
    batches = list(src.iter_batches(batch_size=1_024))
    assert sum(len(b) for b in batches) == keys.shape[0]
    np.testing.assert_array_equal(
        np.concatenate([b.keys for b in batches]), keys)
    ts = np.concatenate([b.timestamps for b in batches])
    np.testing.assert_array_equal(ts,
                                  np.arange(keys.shape[0]) * (1.0 / 2e4))
    # batch form rejects per-source columns
    with pytest.raises(ValueError, match="inside each RecordBatch"):
        Source(batches=batches, values=np.ones(3))


def test_session_misuse_raises(keys):
    eng = SimulatorEngine()
    topo = _single("fg")
    session = eng.open(topo)
    with pytest.raises(TypeError, match="RecordBatch"):
        session.feed(keys)
    with pytest.raises(ValueError, match="no stage named"):
        session.advance([ScopedEvent("nope",
                                     MembershipEvent(at=0, workers=(0,)))])
    with pytest.raises(ValueError, match="no address"):
        # the at=-1 default means "address me via at_time()" — forgetting
        # the wrapper must not silently drop the event
        session.advance([ScopedEvent("worker",
                                     MembershipEvent(workers=(0, 1)))])
    with pytest.raises(ValueError, match="batch_size must be positive"):
        list(Source(keys, arrival_rate=2e4).iter_batches(batch_size=-2))
    session.feed(RecordBatch(keys[:100], np.arange(100) * 1e-4))
    with pytest.raises(ValueError, match="time-ordered"):
        session.feed(RecordBatch(keys[:100], np.arange(100) * 1e-6))
    session.close()
    with pytest.raises(RuntimeError, match="closed"):
        session.feed(RecordBatch(keys[:10], np.arange(10) * 1.0))
    with pytest.raises(RuntimeError, match="closed"):
        session.close()


def test_record_batches_adapter_replays_token_stream():
    batches = list(record_batches(num_docs=700, num_keys=50, doc_len=8,
                                  vocab_size=64, batch=256,
                                  arrival_rate=1e4, seed=0))
    assert [len(b) for b in batches] == [256, 256, 188]
    ts = np.concatenate([b.timestamps for b in batches])
    assert np.all(np.diff(ts) > 0)  # one uniform grid across batches
    for b in batches:
        assert b.keys.dtype == np.int32
        assert b.values is not None
        assert np.all(b.values == np.rint(b.values))  # integral payloads
    # the Table-2 proxy replays end to end through a payload-sum session
    op = TopoWindowOp(agg="sum", size=200, value="payload")
    eng = SimulatorEngine()
    session = eng.open(_single("fish", operator=op), arrival_rate=1e4)
    for b in batches:
        session.feed(b)
    rep = session.close()
    all_keys = np.concatenate([b.keys for b in batches])
    all_vals = np.concatenate([b.values for b in batches])
    assert (rep.state["worker"]["merged"]
            == direct_aggregate(all_keys, op, values=all_vals))
    assert rep.n_source_tuples == 700


# ---------------------------------------------------------------------------
# pane-based sliding windows (ROADMAP item): exactness regression
# ---------------------------------------------------------------------------


def _brute_force_partials(keys, workers, op):
    """The pre-pane per-(window, worker) semantics, computed directly: for
    every sliding window, each worker's aggregate over its routed tuples."""
    n = keys.shape[0]
    out = {}
    for start in range(0, n, op.stride):
        lo, hi = start, min(start + op.size, n)
        for i in range(lo, hi):
            k, w = int(keys[i]), int(workers[i])
            d = out.setdefault((start, w), {})
            d[k] = d.get(k, 0) + 1
    return out


def test_pane_composition_matches_per_window_semantics():
    rng = np.random.default_rng(11)
    n = 3_000
    keys = rng.integers(0, 120, n).astype(np.int64)
    workers = rng.integers(0, 5, n).astype(np.int64)
    op = WindowOp(agg="count", size=800, slide=200)
    mgr = KeyedStateManager(op)
    for lo in range(0, n, 700):  # uneven chunks across pane boundaries
        mgr.feed(keys[lo:lo + 700], workers[lo:lo + 700])
    mgr.finalize()
    got = {(p.window, p.worker): dict(zip(p.keys.tolist(),
                                          p.values.tolist()))
           for p in mgr.partials}
    assert got == _brute_force_partials(keys, workers, op)
    # pane layout: live entries are bounded by the tuples inside the
    # retained panes (each tuple counted once), not by every open window's
    # full key set (the per-window layout held each key size/slide times)
    assert (mgr.state_bytes_peak
            <= (op.size // op.stride + 1) * op.stride * 12)


def test_pane_sliding_windows_exact_under_churn_multi_feed(keys):
    op = TopoWindowOp(agg="count", size=2_000, slide=500)
    topo = _single("fish", operator=op)
    src = Source(keys, arrival_rate=2e4)
    ev = [ScopedEvent("worker",
                      MembershipEvent(at=2_300, workers=tuple(range(7))))]
    rep = _session_run(SimulatorEngine(), topo, src, ev, feeds=5)
    st = rep.state["worker"]
    assert st["merged"] == direct_aggregate(keys, op)
    assert st["windows"] == len(range(0, keys.shape[0], 500))
    assert st["migration_bytes"] > 0
