"""Unit + property tests for the FISH core algorithms (paper Algs. 1-3)."""

import math

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (EpochFrequencyTracker, FishParams, chk_num_workers,
                        classify_hot_keys, epoch_update, init_fish_state)
from repro.data.synthetic import zipf_time_evolving


# ---------------------------------------------------------------------------
# Alg. 1 — sequential tracker
# ---------------------------------------------------------------------------


def test_counts_exact_when_under_capacity():
    t = EpochFrequencyTracker(FishParams(alpha=0.5, epoch=10**9, k_max=100))
    keys = [1, 2, 2, 3, 3, 3]
    t.update_many(keys)
    assert t.counts == {1: 1.0, 2: 2.0, 3: 3.0}


def test_replace_min_inherits_count():
    """Alg. 1 line 22: new key gets c_min + 1, not 1."""
    t = EpochFrequencyTracker(FishParams(alpha=0.5, epoch=10**9, k_max=2))
    t.update_many([1, 1, 1, 2])
    t.update(99)  # evicts key 2 (count 1) -> c_99 = 2
    assert 99 in t.counts and t.counts[99] == 2.0
    assert 2 not in t.counts


def test_epoch_decay_applied_every_epoch():
    p = FishParams(alpha=0.5, epoch=4, k_max=10)
    t = EpochFrequencyTracker(p)
    t.update_many([7, 7, 7, 7])      # epoch fills; decay fires on next tuple
    assert t.counts[7] == 4.0
    t.update(7)                      # decay: 4*0.5=2, then +1
    assert t.counts[7] == 3.0
    assert t.epochs_completed == 1


def test_alpha_zero_forgets_everything():
    p = FishParams(alpha=0.0, epoch=2, k_max=10)
    t = EpochFrequencyTracker(p)
    t.update_many([5, 5, 9])
    assert t.counts[9] == 1.0
    assert t.counts.get(5, 0.0) == 0.0  # cleared at the epoch boundary


@given(st.lists(st.integers(0, 50), min_size=1, max_size=500),
       st.integers(2, 20))
@settings(max_examples=50, deadline=None)
def test_spacesaving_error_bound(keys, k_max):
    """SpaceSaving invariant (no decay): count overestimates true frequency
    by at most N/K_max."""
    t = EpochFrequencyTracker(FishParams(alpha=1.0, epoch=10**9, k_max=k_max))
    t.update_many(keys)
    n = len(keys)
    true = {}
    for k in keys:
        true[k] = true.get(k, 0) + 1
    for k, c in t.counts.items():
        assert c >= true.get(k, 0) - 1e-9          # never underestimates
        assert c <= true.get(k, 0) + n / k_max + 1e-9

    assert len(t.counts) <= k_max


@given(st.lists(st.integers(0, 30), min_size=1, max_size=400))
@settings(max_examples=30, deadline=None)
def test_bounded_memory(keys):
    p = FishParams(alpha=0.3, epoch=16, k_max=8)
    t = EpochFrequencyTracker(p)
    t.update_many(keys)
    assert len(t.counts) <= p.k_max


def test_hot_keys_detects_time_evolving_flip():
    """After the ZF hot-set flip (§6.1), the tracker must follow the new head."""
    p = FishParams(alpha=0.2, epoch=1000, k_max=200)
    t = EpochFrequencyTracker(p)
    keys = zipf_time_evolving(30_000, num_keys=5_000, z=1.5, flip_head=1000,
                              seed=1)
    t.update_many(keys[:24_000].tolist())
    hot_before = set(t.hot_keys(16))
    t.update_many(keys[24_000:].tolist())
    hot_after = set(t.hot_keys(16))
    # flipped distribution: Pr[i] ∝ (1000 - i + 1)^-z -> head near key ~999
    assert hot_before, "no hot keys detected before flip"
    assert hot_after, "no hot keys detected after flip"
    assert any(k > 900 for k in hot_after), f"stale hot set: {hot_after}"


# ---------------------------------------------------------------------------
# Alg. 2 — CHK
# ---------------------------------------------------------------------------


def test_chk_nonhot_gets_two_workers():
    d, m = chk_num_workers(0.001, 0.5, theta=0.01, num_workers=64)
    assert d == 2 and m == 0


def test_chk_top_key_gets_all_workers():
    d, m = chk_num_workers(0.5, 0.5, theta=0.01, num_workers=64)
    assert d == 64 and m == 64


def test_chk_power_of_two_hierarchy():
    # f_top/f = 4 -> index 2 -> d = W/4
    d, _ = chk_num_workers(0.1, 0.4, theta=0.01, num_workers=64)
    assert d == 16


def test_chk_monotone_memory():
    # M_k never lets d shrink (Alg. 2 lines 7-10)
    d1, m = chk_num_workers(0.5, 0.5, theta=0.01, num_workers=64, m_k=0)
    d2, m = chk_num_workers(0.05, 0.5, theta=0.01, num_workers=64, m_k=m)
    assert d2 == d1 == 64


@given(st.floats(1e-6, 1.0), st.floats(1e-6, 1.0), st.integers(2, 256))
@settings(max_examples=100, deadline=None)
def test_chk_bounds(f_k, f_top, w):
    f_top = max(f_k, f_top)
    d, _ = chk_num_workers(f_k, f_top, theta=0.25 / w, num_workers=w)
    assert 2 <= d <= w


# ---------------------------------------------------------------------------
# Device-side epoch_update vs. the sequential oracle
# ---------------------------------------------------------------------------


def test_epoch_update_matches_oracle_hot_sets():
    import jax.numpy as jnp

    p = FishParams(alpha=0.2, epoch=1000, k_max=256)
    keys = zipf_time_evolving(16_000, num_keys=2_000, z=1.4, seed=7
                              ).astype(np.int32)
    seq = EpochFrequencyTracker(p)
    seq.update_many(keys.tolist())

    st_dev = init_fish_state(p.k_max)
    for i in range(0, len(keys), p.epoch):
        st_dev = epoch_update(st_dev, jnp.asarray(keys[i:i + p.epoch]),
                              alpha=p.alpha, max_new=64)
    top_seq = set(sorted(seq.counts, key=seq.counts.get, reverse=True)[:20])
    ks = np.asarray(st_dev["keys"])
    cs = np.asarray(st_dev["counts"])
    top_dev = set(ks[np.argsort(-cs)][:20].tolist())
    jac = len(top_seq & top_dev) / len(top_seq | top_dev)
    assert jac >= 0.6, f"device/oracle hot-set Jaccard too low: {jac}"


def test_classify_hot_keys_vectorised_matches_scalar():
    import jax.numpy as jnp

    state = init_fish_state(8)
    state["keys"] = jnp.arange(8, dtype=jnp.int32)
    counts = jnp.asarray([100.0, 50.0, 25.0, 12.0, 6.0, 3.0, 1.0, 1.0])
    state["counts"] = counts
    w = 64
    theta = 0.25 / w
    d, is_hot, _ = classify_hot_keys(state, num_workers=w, theta=theta)
    total = float(counts.sum())
    f_top = float(counts.max()) / total
    for i in range(8):
        f_k = float(counts[i]) / total
        d_ref, _ = chk_num_workers(f_k, f_top, theta, w)
        if f_k > theta:
            assert int(d[i]) == d_ref, (i, int(d[i]), d_ref)
        else:
            assert int(d[i]) == 2
