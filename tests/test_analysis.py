"""repro.analysis (ISSUE 7): lint rules, contracts, baseline, CLI gate.

Golden findings per fixture (rule id + line), a zero-finding pass over the
clean fixture, baseline mechanics, runtime parity for the static
topology/config mirrors, and the repo-wide gate: the current tree scans
clean against the checked-in ``analysis_baseline.json``.
"""

import json
from pathlib import Path

import pytest

from repro.analysis import contracts
from repro.analysis.callgraph import lint_program
from repro.analysis.cli import main as analysis_main
from repro.analysis.findings import Baseline, Finding, apply_baseline
from repro.analysis.lint import RULES, iter_python_files, lint_file

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "analysis_fixtures"
PROGRAM = FIXTURES / "program"

# golden (line, severity) findings per fixture file — every shipped rule
# demonstrably fires, at exactly these sites and no others
GOLDEN = {
    "host_sync_fixture.py": {
        "host-sync-in-jit": {(11, "error"), (12, "error"), (13, "error"),
                             (14, "error"), (15, "error"), (20, "error")},
    },
    "retrace_fixture.py": {
        "retrace-hazard": {(8, "warn"), (13, "error"), (21, "warn")},
    },
    "np_mix_fixture.py": {
        "np-jnp-mixing": {(12, "error"), (13, "error")},
    },
    "frozen_fixture.py": {
        "frozen-mutation": {(11, "note"), (14, "error"), (18, "error"),
                            (19, "error"), (20, "error")},
    },
    "shim_fixture.py": {
        "deprecated-shim": {(7, "error"), (8, "error")},
    },
    "unordered_fixture.py": {
        "unordered-iteration": {(7, "warn"), (9, "warn"), (10, "warn")},
    },
    "contract_fixture.py": {
        "exactness-contract": {(3, "error"), (4, "error"), (5, "error")},
    },
    "topology_fixture.py": {
        "topology-config": {(5, "error"), (6, "error"), (7, "error"),
                            (8, "error"), (9, "error"), (10, "error"),
                            (12, "error")},
    },
    "registry_fixture.py": {
        "registry-counter-mutation": {(8, "error"), (9, "error"),
                                      (10, "error"), (18, "error"),
                                      (26, "error"), (27, "error")},
    },
    # ISSUE 10: determinism & numerics rules
    "overflow_fixture.py": {
        "int32-overflow": {(9, "error"), (15, "error"), (21, "error"),
                           (30, "error"), (31, "error")},
    },
    "rng_fixture.py": {
        "unseeded-rng": {(10, "error"), (11, "error"), (12, "error"),
                         (13, "error"), (14, "error"), (15, "error"),
                         (16, "error")},
    },
    "wallclock_fixture.py": {
        "wall-clock-leak": {(5, "warn"), (10, "warn"), (15, "warn")},
    },
    "sig_fixture.py": {
        "unbounded-signature": {(12, "warn")},
    },
    "interproc_fixture.py": {
        "interproc-unordered-iteration": {(13, "warn"), (15, "warn")},
    },
}

#: one near-miss clean fixture per ISSUE-10 rule (plus the original):
#: similar shape, zero findings across *all* rules
CLEAN_FIXTURES = (
    "clean_fixture.py",
    "overflow_clean_fixture.py",   # int64 accumulators / unaccumulated ids
    "rng_clean_fixture.py",        # seeded, threaded generators
    "wallclock_clean_fixture.py",  # elapsed-time print that never escapes
    "sig_clean_fixture.py",        # pow2-bucketed / boolean key elements
    "interproc_clean_fixture.py",  # sorted at the set boundary
)


# ---------------------------------------------------------------------------
# golden findings: each rule fires exactly where the fixture says
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fixture", sorted(GOLDEN))
def test_fixture_golden_findings(fixture):
    found = lint_file(FIXTURES / fixture, REPO)
    got = {}
    for f in found:
        got.setdefault(f.rule, set()).add((f.line, f.severity))
    assert got == GOLDEN[fixture]
    for f in found:
        assert f.message and f.hint  # every finding carries a fix-it hint


@pytest.mark.parametrize("fixture", CLEAN_FIXTURES)
def test_clean_fixture_has_no_findings(fixture):
    assert lint_file(FIXTURES / fixture, REPO) == []


def test_cross_module_traced_closure_and_interproc():
    """The whole-program layer sees what per-file scans cannot: a hazard
    in a helper module only traced through another module's jit root, and
    iteration over an imported set-returning callee."""
    findings = lint_program([PROGRAM], REPO, excludes=())
    by = {}
    for f in findings:
        by.setdefault(Path(f.path).name, set()).add((f.rule, f.line))
    assert by == {
        "xjit_b.py": {("host-sync-in-jit", 6), ("np-jnp-mixing", 7)},
        "set_consumer.py": {("interproc-unordered-iteration", 6)},
    }
    # the clean pair stays clean even once traced across the module edge
    assert "xjit_clean_b.py" not in by
    # and the same files are blind spots for the intra-module scan —
    # exactly the gap the call graph closes
    assert lint_file(PROGRAM / "xjit_b.py", REPO) == []
    assert lint_file(PROGRAM / "set_consumer.py", REPO) == []


def test_every_rule_covered_by_a_fixture():
    covered = {rule for per_file in GOLDEN.values() for rule in per_file}
    assert covered == set(RULES)


def test_fixture_dir_excluded_from_default_scan():
    files = iter_python_files([REPO / "tests"])
    assert not any("analysis_fixtures" in f.parts for f in files)
    assert any(f.name == "test_analysis.py" for f in files)


# ---------------------------------------------------------------------------
# the repo gate: current tree is clean against the checked-in baseline,
# and the baseline is exercised by real pre-existing findings
# ---------------------------------------------------------------------------


def _repo_scan():
    paths = [REPO / p for p in ("src", "tests", "benchmarks", "examples")
             if (REPO / p).exists()]
    return lint_program(paths, REPO)


def test_repo_scans_clean_against_baseline():
    findings = _repo_scan()
    baseline = Baseline.load(REPO / "analysis_baseline.json")
    fresh, stale = apply_baseline(findings, baseline)
    assert fresh == [], "new findings:\n" + "\n".join(
        f.format() for f in fresh)
    assert stale == [], f"stale baseline entries (fixed? remove): {stale}"
    # no rule is fixture-only: the baseline carries real-tree findings
    assert len(findings) > 0
    baselined_rules = {fp.split("::", 1)[0] for fp in baseline.entries}
    assert baselined_rules  # ≥1 rule fired on real pre-existing code


def test_baseline_justifications_are_real():
    baseline = Baseline.load(REPO / "analysis_baseline.json")
    for fp, (count, why) in baseline.entries.items():
        assert count >= 1
        assert len(why) > 20 and "TODO" not in why, fp


# ---------------------------------------------------------------------------
# baseline mechanics
# ---------------------------------------------------------------------------


def _finding(rule="host-sync-in-jit", path="src/x.py", line=3,
             scope="f") -> Finding:
    return Finding(rule=rule, path=path, line=line, col=0,
                   severity="error", message="m", hint="h", scope=scope)


def test_baseline_suppresses_by_fingerprint_and_count():
    f1, f2 = _finding(line=3), _finding(line=9)  # same scope: same print
    b = Baseline({f1.fingerprint: (1, "justified")})
    fresh, stale = apply_baseline([f1, f2], b)
    assert fresh == [f2]  # count=1 covers one instance; the excess is new
    assert stale == []
    fresh2, _ = apply_baseline(
        [f1, f2], Baseline({f1.fingerprint: (2, "justified")}))
    assert fresh2 == []


def test_baseline_fingerprint_survives_line_drift():
    before, after = _finding(line=3), _finding(line=40)
    assert before.fingerprint == after.fingerprint


def test_baseline_reports_stale_entries():
    b = Baseline({"deprecated-shim::src/gone.py::f": (1, "was justified")})
    fresh, stale = apply_baseline([], b)
    assert fresh == [] and stale == ["deprecated-shim::src/gone.py::f"]


def test_baseline_requires_why(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"version": 1, "accepted": [
        {"fingerprint": "r::p::s", "count": 1, "why": "  "}]}))
    with pytest.raises(ValueError, match="why"):
        Baseline.load(p)


def test_baseline_write_roundtrip(tmp_path):
    p = tmp_path / "b.json"
    f = _finding()
    Baseline({f.fingerprint: (1, "kept justification")}).dump(
        p, findings=[f, _finding(line=9)])
    loaded = Baseline.load(p)
    assert loaded.entries[f.fingerprint] == (2, "kept justification")
    # dump always writes schema v2, stamped with the audited scale target
    data = json.loads(p.read_text())
    assert data["version"] == 2
    assert data["scale_target"] == contracts.SCALE_TARGET
    assert loaded.scale_target == contracts.SCALE_TARGET


def test_baseline_v1_still_loads(tmp_path):
    """Migration path: a v1 baseline (no scale_target) loads as legacy."""
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"version": 1, "accepted": [
        {"fingerprint": "r::p::s", "count": 1, "why": "old justification"}]}))
    b = Baseline.load(p)
    assert b.entries["r::p::s"] == (1, "old justification")
    assert b.scale_target is None


def test_baseline_v2_pins_scale_target(tmp_path):
    """v2 requires scale_target, and it must match contracts.SCALE_TARGET —
    moving the target invalidates every audited justification loudly."""
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"version": 2, "accepted": []}))
    with pytest.raises(ValueError, match="scale_target"):
        Baseline.load(p)
    p.write_text(json.dumps({
        "version": 2, "scale_target": contracts.SCALE_TARGET * 100,
        "accepted": []}))
    with pytest.raises(ValueError, match="re-audit"):
        Baseline.load(p)
    p.write_text(json.dumps({
        "version": 2, "scale_target": contracts.SCALE_TARGET,
        "accepted": []}))
    assert Baseline.load(p).scale_target == contracts.SCALE_TARGET


def test_checked_in_baseline_is_v2():
    data = json.loads((REPO / "analysis_baseline.json").read_text())
    assert data["version"] == 2
    assert data["scale_target"] == contracts.SCALE_TARGET


# ---------------------------------------------------------------------------
# contracts: the exactness table is the single source of truth
# ---------------------------------------------------------------------------


def test_exactness_table_shape():
    assert set(contracts.EXACTNESS) == {
        (s, m) for s in contracts.SCHEMES for m in contracts.ENGINE_MODES}
    # the reference oracle is trivially exact for every scheme
    assert all(contracts.exactness(s, "reference") == contracts.EXACT
               for s in contracts.SCHEMES)
    # batched and fused carry the same routing contract per scheme
    for s in contracts.SCHEMES:
        assert contracts.exactness(s, "batched") == \
            contracts.exactness(s, "fused")


def test_exactness_partitions():
    assert set(contracts.EXACT_SCHEMES) | set(contracts.BANDED_SCHEMES) \
        == set(contracts.SCHEMES)
    assert not set(contracts.EXACT_SCHEMES) & set(contracts.BANDED_SCHEMES)
    assert contracts.DRIFT_SCHEMES == contracts.BANDED_SCHEMES
    with pytest.raises(ValueError):
        contracts.exactness("nope", "fused")
    with pytest.raises(ValueError):
        contracts.exactness("sg", "warp")


def test_static_mirrors_match_runtime_validators():
    """Where the static mirror reports an error, the runtime constructor
    raises — and vice versa for the valid cases the fixture keeps."""
    from repro.topology import Edge, Stage, Topology, config_for

    # literal args go through variables so the repo scan of this test file
    # does not itself trip the topology-config rule it is testing
    bad_scheme, bad_alpha = "nope", 1.5
    assert contracts.validate_config_literal("fish", {"alpha": bad_alpha})
    with pytest.raises(ValueError):
        config_for("fish", alpha=bad_alpha)
    with pytest.raises((KeyError, ValueError)):
        config_for(bad_scheme)
    assert contracts.validate_config_literal("fish", {"alpha": 0.5}) is None

    reserved, zero = "source", 0
    assert contracts.validate_stage_literal(reserved, 4)
    with pytest.raises(ValueError):
        Stage(reserved, 4)
    assert contracts.validate_stage_literal("work", zero)
    with pytest.raises(ValueError):
        Stage("work", zero)
    assert contracts.validate_stage_literal("work", 4) is None

    a = "a"  # indirection: keeps the repo scan of this file itself clean
    assert contracts.validate_edge_literal(a, a)
    with pytest.raises(ValueError):
        Edge(a, a, config_for("sg"))
    assert contracts.validate_edge_literal("source", a) is None

    dup = [a, a]
    assert contracts.validate_topology_literal(dup, [("source", a)])
    with pytest.raises(ValueError):
        Topology(name="dup",
                 stages=(Stage(a, 2), Stage(a, 2)),
                 edges=(Edge("source", a, config_for("sg")),))
    assert contracts.validate_topology_literal(
        ["a", "b"], [("source", "a"), ("a", "b")]) == []
    # fan-in and disconnection are both promoted to pre-run errors
    assert contracts.validate_topology_literal(
        ["a", "b"], [("source", "a"), ("source", "b"), ("a", "b")])
    assert contracts.validate_topology_literal(["a"], [])


# ---------------------------------------------------------------------------
# auditor mechanics (the engine-level budgets live in test_fused_engine)
# ---------------------------------------------------------------------------


def test_trace_budget_guard():
    from repro.analysis.audit import TraceBudget
    from repro.kernels import feed_fused

    with TraceBudget(1):
        feed_fused.TRACE_COUNT += 1
    with pytest.raises(AssertionError, match="traces > budget"):
        with TraceBudget(0, what="guarded block"):
            feed_fused.TRACE_COUNT += 1


def test_auditor_rejects_unknown_sync_context():
    from repro.analysis.audit import EdgeAuditor

    class _Stub:
        begin_feed = run_segment = flush_pane = host_sync = \
            refresh_membership = staticmethod(lambda *a, **k: None)

    with EdgeAuditor(_Stub()) as aud:
        with pytest.raises(ValueError, match="unknown sync context"):
            with aud.expect("metrics"):
                pass


# ---------------------------------------------------------------------------
# CLI gate: red on an injected violation, green when clean
# ---------------------------------------------------------------------------


def _write_violation(tmp_path: Path) -> Path:
    bad = tmp_path / "injected.py"
    bad.write_text(
        "from repro.core import make_grouper\n"
        "g = make_grouper('pkg', 4)\n")
    return bad


def test_cli_red_on_injected_violation(tmp_path, capsys):
    bad = _write_violation(tmp_path)
    rc = analysis_main([str(bad), "--no-baseline"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "deprecated-shim" in out and "injected.py:2" in out


def test_cli_green_on_clean_file(tmp_path):
    ok = tmp_path / "ok.py"
    ok.write_text("x = 1\n")
    assert analysis_main([str(ok), "--no-baseline"]) == 0


def test_cli_baseline_cycle(tmp_path, capsys):
    bad = _write_violation(tmp_path)
    base = tmp_path / "base.json"
    assert analysis_main([str(bad), "--write-baseline", str(base)]) == 0
    data = json.loads(base.read_text())
    assert data["accepted"][0]["why"].startswith("TODO")
    # an unjustified baseline is rejected outright
    assert analysis_main([str(bad), "--baseline", str(base)]) == 1
    data["accepted"][0]["why"] = "intentional shim-compat test double"
    base.write_text(json.dumps(data))
    assert analysis_main([str(bad), "--baseline", str(base)]) == 0
    # a second instance of the same fingerprint is new again
    bad.write_text(bad.read_text() + "h = make_grouper('pkg', 8)\n")
    assert analysis_main([str(bad), "--baseline", str(base)]) == 1


def test_cli_json_artifact(tmp_path):
    bad = _write_violation(tmp_path)
    report = tmp_path / "findings.json"
    rc = analysis_main([str(bad), "--no-baseline", "--json", str(report),
                        "--quiet"])
    assert rc == 1
    data = json.loads(report.read_text())
    assert data["new"] == data["total"] == 1
    (entry,) = data["findings"]
    assert entry["rule"] == "deprecated-shim" and entry["new"]


def test_cli_usage_errors(tmp_path):
    assert analysis_main([str(tmp_path / "missing.py")]) == 2
    assert analysis_main(["--rules", "not-a-rule",
                          str(tmp_path)]) == 2
