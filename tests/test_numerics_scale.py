"""Regression tests for >2^31 aggregates (ISSUE 10 satellite 1).

The device store accumulates in int32 on device (no x64), so lifetime
totals past 2^31 must flow through the generational spill into the host
int64 base; the fused feed uploads per-worker counts as int32, so counts
past 2^31 must survive the rebase/readback round trip.  Both paths feed
billing (``size_bytes``/``MigrationBiller``), which is where silent
wraparound would turn into silently-wrong charges.
"""
import numpy as np
import pytest

from repro.core.stream import simulate_edge
from repro.state.migration import MigrationBiller, MigrationStats
from repro.state.store import (ENTRY_BYTES, ArrayStateStore,
                               DeviceStateStore, DictStateStore)
from repro.topology.configs import config_for

INT32_MAX = 2 ** 31 - 1


def test_device_store_lifetime_totals_past_int32():
    st = DeviceStateStore()
    chunk = 2 ** 30
    for _ in range(3):  # 3 * 2^30 > INT32_MAX: forces at least one spill
        st.merge_entries(np.array([3, 7], dtype=np.int64),
                         np.array([chunk, chunk], dtype=np.int64),
                         np.array([chunk, chunk], dtype=np.int64))
    ks, vs, cs = st.items()
    assert ks.tolist() == [3, 7]
    assert vs.tolist() == [3 * chunk, 3 * chunk]
    assert cs.tolist() == [3 * chunk, 3 * chunk]
    assert vs.dtype == np.int64 and min(vs) > INT32_MAX
    # the young generation must have spilled into the int64 base
    assert st._base_c.max() > 0
    vals, cnts = st.take(np.array([3], dtype=np.int64))
    assert vals.tolist() == [3 * chunk] and cnts.tolist() == [3 * chunk]
    assert st.num_entries == 1  # key 3 drained, key 7 intact
    _, vs2, _ = st.items()
    assert vs2.tolist() == [3 * chunk]


def test_device_store_spill_survives_key_rebuild():
    """Inserting unseen keys after a spill must realign the int64 base."""
    st = DeviceStateStore()
    big = 2 ** 30
    st.merge_entries(np.array([10], dtype=np.int64),
                     np.array([big], dtype=np.int64),
                     np.array([big], dtype=np.int64))
    st.merge_entries(np.array([10], dtype=np.int64),
                     np.array([big], dtype=np.int64),
                     np.array([big], dtype=np.int64))
    # key 5 sorts *before* key 10: the rebuild shifts device slots and
    # must shift the spilled base with them
    st.merge_entries(np.array([5, 10], dtype=np.int64),
                     np.array([1, big], dtype=np.int64),
                     np.array([1, big], dtype=np.int64))
    ks, vs, cs = st.items()
    assert ks.tolist() == [5, 10]
    assert vs.tolist() == [1, 3 * big]
    assert cs.tolist() == [1, 3 * big]


def test_device_store_matches_dict_reference_under_repeated_merges():
    rng = np.random.default_rng(11)
    dev, ref = DeviceStateStore(), DictStateStore()
    for _ in range(12):
        keys = np.unique(rng.integers(0, 40, size=16))
        vals = rng.integers(1, 2 ** 30, size=keys.shape[0])
        cnts = rng.integers(1, 2 ** 30, size=keys.shape[0])
        dev.merge_entries(keys, vals, cnts)
        ref.merge_entries(keys, vals, cnts)
    dk, dv, dc = dev.items()
    rk, rv, rc = ref.items()
    order = np.argsort(rk)
    np.testing.assert_array_equal(dk, rk[order])
    np.testing.assert_array_equal(dv, rv[order])
    np.testing.assert_array_equal(dc, rc[order])


def test_array_store_totals_past_int32():
    st = ArrayStateStore()
    chunk = 2 ** 30
    for _ in range(3):
        st.merge_entries(np.array([1], dtype=np.int64),
                         np.array([chunk], dtype=np.int64),
                         np.array([chunk], dtype=np.int64))
    _, vs, cs = st.items()
    assert vs.tolist() == [3 * chunk] and cs.tolist() == [3 * chunk]


def test_fused_counts_survive_int32_rebase():
    """A grouper whose lifetime per-worker counts already exceed int32
    must route identically to a fresh one (pkg compares counts only
    pairwise) and read exact counts back from the fused kernel."""
    rng = np.random.default_rng(5)
    keys = rng.integers(0, 500, size=4_000)
    offset = 2 ** 31 + 5

    g_fresh = config_for("pkg").build(8)
    g_aged = config_for("pkg").build(8)
    g_aged.assigned_counts += offset  # uniform: preserves comparisons
    assert g_aged.assigned_counts.dtype == np.int64

    r_fresh = simulate_edge(g_fresh, keys, arrival_rate=2e4, mode="fused",
                            capacities=np.full(8, 4e-4))
    r_aged = simulate_edge(g_aged, keys, arrival_rate=2e4, mode="fused",
                           capacities=np.full(8, 4e-4))
    deltas = g_aged.assigned_counts - offset
    np.testing.assert_array_equal(deltas, g_fresh.assigned_counts)
    assert int(g_aged.assigned_counts.max()) > INT32_MAX
    assert int(deltas.sum()) == keys.shape[0]
    np.testing.assert_array_equal(r_aged.finishes, r_fresh.finishes)


def test_fused_rejects_int32_breaking_count_spread():
    """A non-uniform spread the rebase cannot absorb fails loudly, not
    with wraparound."""
    rng = np.random.default_rng(6)
    keys = rng.integers(0, 500, size=1_000)
    g = config_for("pkg").build(8)
    g.assigned_counts[0] += 2 ** 31 + 5  # spread itself exceeds int32
    with pytest.raises(ValueError, match="int32"):
        simulate_edge(g, keys, arrival_rate=2e4, mode="fused",
                      capacities=np.full(8, 4e-4))


def test_migration_bill_exact_past_int32_entries():
    """A synthetic >2^31 entry count billed through MigrationBiller must
    charge the exact amount (host path is int64/float, no wrap)."""
    entries = 2 ** 31 + 9
    stats = MigrationStats()
    stats.last_recv_entries = {2: entries}
    biller = MigrationBiller(stats, cost_per_byte=1.0)
    biller.on_event("post_membership", None)
    charges = biller.pop_charges()
    assert charges == {2: float(entries * ENTRY_BYTES)}
    assert biller.billed_total == float(entries * ENTRY_BYTES)
    # and the stats byte counter itself is a plain int, not a wrapped one
    stats.bytes_moved += entries * ENTRY_BYTES
    assert stats.bytes_moved == entries * ENTRY_BYTES > INT32_MAX
