"""Alg. 3 heuristic worker assignment: Eq. 1 backlog inference + Eq. 2 argmin."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import WorkerStateEstimator, select_min_wait


def test_selects_min_estimated_wait():
    # paper Fig. 7: W1..W4, PC(W3)=PC(W4)=0.5x time/tuple of W1/W2
    est = WorkerStateEstimator(capacities=np.array([1.0, 1.0, 0.5, 0.5]),
                               interval=10.0)
    est.backlog = np.array([50.0, 40.0, 200.0, 120.0])
    # waits: 50, 40, 100, 60 -> W2 (index 1)
    assert est.select([0, 1, 2, 3]) == 1


def test_backlog_inference_eq1():
    est = WorkerStateEstimator(capacities=np.array([2.0]), interval=10.0)
    est.backlog = np.array([5.0])
    est.assigned = np.array([3.0])
    # ((5+3)*2 - 11)/2 = 2.5 tuples left after 11s of work
    est.maybe_estimate(now=11.0)
    assert est.backlog[0] == pytest.approx(2.5)
    assert est.assigned[0] == 0.0


def test_backlog_clamped_at_zero():
    est = WorkerStateEstimator(capacities=np.array([0.1]), interval=1.0)
    est.backlog = np.array([2.0])
    est.maybe_estimate(now=100.0)
    assert est.backlog[0] == 0.0


def test_assignment_counts_accumulate():
    est = WorkerStateEstimator(capacities=np.ones(3), interval=10.0)
    for _ in range(9):
        est.select([0, 1, 2])
    # round-robin-ish under equal capacity: each got some work
    assert est.assigned.sum() == 9
    assert (est.assigned > 0).all()


def test_heterogeneous_workers_prefer_fast():
    est = WorkerStateEstimator(capacities=np.array([1.0, 0.25]), interval=1e9)
    picks = [est.select([0, 1]) for _ in range(20)]
    # fast worker should absorb ~4x the tuples
    assert picks.count(1) > picks.count(0)


@given(st.lists(st.floats(0.01, 10.0), min_size=2, max_size=16),
       st.lists(st.floats(0.0, 100.0), min_size=2, max_size=16))
@settings(max_examples=50, deadline=None)
def test_select_is_argmin_of_wait(caps, backlog):
    n = min(len(caps), len(backlog))
    caps, backlog = np.array(caps[:n]), np.array(backlog[:n])
    est = WorkerStateEstimator(capacities=caps, interval=1e9)
    est.backlog = backlog.copy()
    w = est.select(range(n))
    waits = backlog * caps
    assert waits[w] == pytest.approx(waits.min())


def test_device_side_select_min_wait():
    import jax.numpy as jnp

    backlog = jnp.asarray([3.0, 1.0, 10.0, 2.0])
    caps = jnp.asarray([1.0, 5.0, 0.1, 1.0])
    mask = jnp.asarray([[True, True, True, True],
                        [True, False, True, False]])
    picks = select_min_wait(backlog, caps, mask)
    # waits = [3, 5, 1, 2] -> row0: idx2; row1 (cands 0,2): idx2
    assert picks.tolist() == [2, 2]
