"""Batched grouping engine vs the sequential reference oracle (ISSUE 1).

Contract (DESIGN.md §6):

* SG / FG / PKG — *identical* assignments and metrics: the batched paths are
  exact vectorisations (round-robin arithmetic, cached unique-key hashes,
  cumulative-count two-choice loop).
* DC / WC / FISH — *bounded divergence*: frequencies are read at sub-chunk
  granularity and Alg. 3 is water-filled per unique key, so individual
  assignments may differ but the paper's metrics must stay within tight
  bands of the oracle.
* the fused Pallas epoch kernel matches the unfused jnp pipeline
  (``_match_counts`` + segment-count) slot for slot.
"""

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.analysis.contracts import DRIFT_SCHEMES, EXACT_SCHEMES
from repro.core import simulate_edge
from repro.topology import build_grouper
from repro.data.synthetic import intern_keys, zipf_time_evolving


def _sim_batched(g, keys, **kw):
    return simulate_edge(g, keys, mode="batched", **kw).metrics


def _sim_reference(g, keys, **kw):
    return simulate_edge(g, keys, mode="reference", **kw).metrics


@pytest.fixture(scope="module")
def keys():
    return zipf_time_evolving(30_000, num_keys=3_000, z=1.4, seed=0)


def _pair(scheme, keys, workers=16, **kw):
    m_ref = _sim_reference(
        build_grouper(scheme, workers), keys, arrival_rate=2e4, **kw
    )
    m_bat = _sim_batched(
        build_grouper(scheme, workers), keys, arrival_rate=2e4, **kw
    )
    return m_ref, m_bat


# ---------------------------------------------------------------------------
# assign_batch-level equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", EXACT_SCHEMES)
def test_assign_batch_exact(scheme, keys):
    g_ref = build_grouper(scheme, 16)
    seq = np.array([g_ref.assign(k, i * 5e-5) for i, k in enumerate(keys)])
    g_bat = build_grouper(scheme, 16)
    bat = g_bat.assign_batch(keys, 0.0, 5e-5)
    np.testing.assert_array_equal(seq, bat)
    np.testing.assert_array_equal(g_ref.assigned_counts, g_bat.assigned_counts)
    assert g_ref.memory_overhead() == g_bat.memory_overhead()


@pytest.mark.parametrize("scheme", DRIFT_SCHEMES)
def test_assign_batch_bounded_drift(scheme, keys):
    g_ref = build_grouper(scheme, 16)
    for i, k in enumerate(keys):
        g_ref.assign(k, i * 5e-5)
    g_bat = build_grouper(scheme, 16)
    g_bat.assign_batch(keys, 0.0, 5e-5)
    c_ref = g_ref.assigned_counts.astype(float)
    c_bat = g_bat.assigned_counts.astype(float)
    # per-worker assigned mass within 15% of the oracle's
    np.testing.assert_allclose(c_bat, c_ref, rtol=0.15, atol=50)
    # replica memory within 20%
    assert g_bat.memory_overhead() == pytest.approx(
        g_ref.memory_overhead(), rel=0.20
    )


# ---------------------------------------------------------------------------
# simulator-level equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", EXACT_SCHEMES)
def test_simulator_metrics_identical(scheme, keys):
    m_ref, m_bat = _pair(scheme, keys)
    for field, v_ref in m_ref.row().items():
        assert m_bat.row()[field] == pytest.approx(v_ref, rel=1e-9), field
    np.testing.assert_allclose(m_bat.per_worker_busy, m_ref.per_worker_busy,
                               rtol=1e-9)


@pytest.mark.parametrize("scheme", DRIFT_SCHEMES)
def test_simulator_metrics_bounded(scheme, keys):
    m_ref, m_bat = _pair(scheme, keys)
    assert m_bat.execution_time == pytest.approx(m_ref.execution_time, rel=0.05)
    assert m_bat.throughput == pytest.approx(m_ref.throughput, rel=0.05)
    assert m_bat.memory_overhead == pytest.approx(m_ref.memory_overhead,
                                                  rel=0.20)
    # load balance must not degrade materially vs the oracle
    assert m_bat.imbalance <= m_ref.imbalance + 0.05
    # queueing latency stays the same order of magnitude
    assert m_bat.latency_p99 <= max(m_ref.latency_p99 * 10.0, 0.05)


def test_simulator_object_keys_fall_back():
    """Non-integer keys take the reference path — loudly (ISSUE 5): the
    10-20x slowdown warns with the offending dtype/shape."""
    str_keys = np.array([f"k{i % 7}" for i in range(300)], dtype=object)
    with pytest.warns(UserWarning, match=r"falling back.*dtype=object.*"
                                         r"shape=\(300,\)"):
        m = _sim_batched(build_grouper("pkg", 4), str_keys, arrival_rate=1e3)
    assert m.execution_time > 0

    # interned ids take the batched path and stay exact vs their own oracle
    ids, vocab = intern_keys(str_keys)
    assert ids.dtype == np.int32 and vocab.shape[0] == 7
    m_bat = _sim_batched(build_grouper("pkg", 4), ids, arrival_rate=1e3)
    m_ref = _sim_reference(build_grouper("pkg", 4), ids,
                                      arrival_rate=1e3)
    assert m_bat.execution_time == pytest.approx(m_ref.execution_time)


def test_assign_batch_and_pipeline_accept_object_keys():
    """String keys must keep working through the batch paths (the caches
    are dtype-agnostic; only replica recording needs the slow path)."""
    from repro.data.pipeline import StreamingPipeline

    str_keys = np.array(["a", "b", "a", "c", "b", "a"] * 40, dtype=object)
    for scheme in EXACT_SCHEMES + DRIFT_SCHEMES:
        g = build_grouper(scheme, 4)
        workers = g.assign_batch(str_keys, 0.0, 1e-4)
        assert workers.shape == str_keys.shape
        assert set(g.replicas) == {"a", "b", "c"}

    pipe = StreamingPipeline(4, 8, 2, grouping="fg")
    pipe.ingest_stream(iter([("docA", np.arange(3)), ("docB", np.arange(2))]))
    assert pipe.memory_overhead() == 2


def test_sampling_and_heterogeneous_capacities_match(keys):
    caps = np.concatenate([np.full(8, 2.0), np.full(8, 1.0)]) * 0.9 * 16 / 2e4
    m_ref, m_bat = _pair("fg", keys[:20_000], capacities=caps,
                         sample_every=4_000)
    for field, v_ref in m_ref.row().items():
        assert m_bat.row()[field] == pytest.approx(v_ref, rel=1e-9), field


# ---------------------------------------------------------------------------
# vectorised CHK vs the scalar Alg. 2
# ---------------------------------------------------------------------------


def test_chk_batch_matches_scalar_elementwise():
    from repro.core import chk_num_workers
    from repro.core.fish import chk_num_workers_batch

    rng = np.random.default_rng(11)
    for w in (2, 16, 64, 256):
        theta = 0.25 / w
        f = np.concatenate([
            rng.uniform(0.0, 1.0, 200),
            np.array([0.0, theta, np.nextafter(theta, 1.0), 1.0]),
        ])
        f_top = float(f.max())
        m_prev = rng.integers(0, w + 1, f.shape[0])
        d_b, m_b = chk_num_workers_batch(f, f_top, theta, w, m_k=m_prev)
        for i in range(f.shape[0]):
            d_s, m_s = chk_num_workers(float(f[i]), f_top, theta, w,
                                       m_k=int(m_prev[i]))
            assert (int(d_b[i]), int(m_b[i])) == (d_s, m_s), (i, f[i])


# ---------------------------------------------------------------------------
# fused Pallas epoch kernel vs the unfused jnp pipeline
# ---------------------------------------------------------------------------


def _fused_vs_unfused(table, tcounts, batch, alpha):
    import jax.numpy as jnp

    from repro.core.fish import _match_counts
    from repro.kernels import ops

    new_c, matched, cand, first = ops.fish_epoch_count(
        jnp.asarray(table), jnp.asarray(tcounts), jnp.asarray(batch),
        alpha=alpha,
    )
    delta, matched_ref = _match_counts(jnp.asarray(table), jnp.asarray(batch))
    np.testing.assert_allclose(np.asarray(new_c),
                               np.asarray(tcounts) * alpha + np.asarray(delta),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(matched), np.asarray(matched_ref))
    # candidate histogram: per-position epoch frequency of its own key,
    # deduped by the first-occurrence flag == np.unique segment counts
    cand = np.asarray(cand)
    first = np.asarray(first)
    uniq, counts = np.unique(batch, return_counts=True)
    seen = {}
    for i, k in enumerate(batch.tolist()):
        assert cand[i] == counts[np.searchsorted(uniq, k)]
        assert first[i] == (k not in seen)
        seen[k] = True


def test_fused_epoch_kernel_matches_unfused():
    rng = np.random.default_rng(3)
    table = np.full(128, -1, np.int32)
    table[:90] = rng.choice(4_000, 90, replace=False)
    tcounts = np.zeros(128, np.float32)
    tcounts[:90] = rng.gamma(2.0, 3.0, 90).astype(np.float32)
    batch = rng.integers(0, 5_000, 1_500).astype(np.int32)
    _fused_vs_unfused(table, tcounts, batch, alpha=0.2)


@given(st.integers(1, 300), st.integers(1, 80), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_fused_epoch_kernel_property(n_keys, n_table, seed):
    rng = np.random.default_rng(seed)
    k_slots = 128
    table = np.full(k_slots, -1, np.int32)
    table[:n_table] = rng.choice(1_000, n_table, replace=False)
    tcounts = np.zeros(k_slots, np.float32)
    tcounts[:n_table] = rng.gamma(2.0, 2.0, n_table).astype(np.float32)
    batch = rng.integers(0, 1_200, n_keys).astype(np.int32)
    _fused_vs_unfused(table, tcounts, batch, alpha=0.5)


def test_epoch_update_partial_epoch_smaller_than_max_new():
    """A final partial epoch with fewer tuples than max_new must not crash
    (top_k k-clamp) on either the jnp or the fused path."""
    import jax.numpy as jnp

    from repro.core.fish import epoch_update, init_fish_state
    from repro.kernels import ops

    state = init_fish_state(128)
    state = epoch_update(state, jnp.arange(10, dtype=jnp.int32), alpha=0.2,
                         max_new=64)
    state = epoch_update(state, jnp.arange(5, 15, dtype=jnp.int32), alpha=0.2,
                         max_new=64, fused_fn=ops.fish_epoch_count)
    assert int((np.asarray(state["keys"]) >= 0).sum()) == 15


def test_epoch_update_fused_tracks_sequential_oracle():
    """End-to-end: fused-kernel epoch_update follows the sequential Alg. 1
    tracker through the ZF hot-set flip (same bound as the jnp path)."""
    import jax.numpy as jnp

    from repro.core import EpochFrequencyTracker, FishParams
    from repro.core.fish import epoch_update, init_fish_state
    from repro.kernels import ops

    p = FishParams(alpha=0.2, epoch=1000, k_max=256)
    zkeys = zipf_time_evolving(16_000, num_keys=2_000, z=1.4, seed=7
                               ).astype(np.int32)
    seq = EpochFrequencyTracker(p)
    seq.update_many(zkeys.tolist())

    state = init_fish_state(p.k_max)
    for i in range(0, len(zkeys), p.epoch):
        state = epoch_update(state, jnp.asarray(zkeys[i:i + p.epoch]),
                             alpha=p.alpha, max_new=64,
                             fused_fn=ops.fish_epoch_count)
    top_seq = set(sorted(seq.counts, key=seq.counts.get, reverse=True)[:20])
    ks = np.asarray(state["keys"])
    cs = np.asarray(state["counts"])
    top_dev = set(ks[np.argsort(-cs)][:20].tolist())
    jac = len(top_seq & top_dev) / len(top_seq | top_dev)
    assert jac >= 0.6, f"fused/oracle hot-set Jaccard too low: {jac}"
