"""Optional-``hypothesis`` shim for the property tests.

The property tests are first-class when ``hypothesis`` is installed (CI
installs it via ``pip install -e .[test]``), but the test suite must still
*collect and run* its deterministic tests in environments without it.
Importing ``given``/``settings``/``st`` from here instead of ``hypothesis``
turns each property test into an explicit skip when the package is missing,
rather than an import-time collection error.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e .[test])"
            )(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategies:
        """Attribute sink: st.<anything>(...) builds inert placeholders."""

        def __getattr__(self, _name):
            def _strategy(*_args, **_kwargs):
                return None

            return _strategy

    st = _Strategies()
