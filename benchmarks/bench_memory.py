"""Paper Fig. 11: memory overhead (normalised to FG) on ZF across skews.

Runs through the unified engine protocol (``run_edge`` → single-edge
Topology on :class:`SimulatorEngine` — ISSUE 3/4) and reports the FG
baseline row explicitly: FG keeps exactly one replica per key, so its
normalised overhead must be 1.0 — the sanity anchor the five compared
schemes are read against.
"""

from __future__ import annotations

import time

from .common import Reporter, run_edge, zf_keys

_BASELINE = "fg"  # norm == 1.0 anchor: one replica per key by construction
_SCHEMES = (_BASELINE, "pkg", "sg", "dc", "wc", "fish")


def run(rep: Reporter) -> dict:
    out = {}
    for z in (1.0, 1.4, 1.8):
        keys = zf_keys(z)
        for w in (16, 64, 128):
            for scheme in _SCHEMES:
                t0 = time.time()
                er = run_edge(scheme, keys, w)
                us = (time.time() - t0) * 1e6
                out[(z, scheme, w)] = er.memory_overhead_norm
                rep.add(f"fig11_mem_vs_fg/zf{z}/{scheme}/w{w}", us,
                        round(er.memory_overhead_norm, 3))
    fg_worst = max(v for (z, s, w), v in out.items() if s == _BASELINE)
    assert abs(fg_worst - 1.0) < 1e-9, \
        f"FG must hold exactly one replica per key, got norm {fg_worst}"
    fish128 = max(v for (z, s, w), v in out.items()
                  if s == "fish" and w == 128)
    sg128 = min(v for (z, s, w), v in out.items() if s == "sg" and w == 128)
    rep.add("fig11/fg_norm_anchor", 0.0, round(fg_worst, 6))
    rep.add("fig11/fish_worst_mem_at_128", 0.0, round(fish128, 3))
    return {"fg_norm_anchor": fg_worst, "fish_worst_mem_128": fish128,
            "sg_best_mem_128": sg128}
