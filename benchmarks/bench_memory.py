"""Paper Fig. 11: memory overhead (normalised to FG) on ZF across skews."""

from __future__ import annotations

import time

from .common import Reporter, run_scheme, zf_keys

_SCHEMES = ("pkg", "sg", "dc", "wc", "fish")


def run(rep: Reporter) -> dict:
    out = {}
    for z in (1.0, 1.4, 1.8):
        keys = zf_keys(z)
        for w in (16, 64, 128):
            for scheme in _SCHEMES:
                t0 = time.time()
                g, m = run_scheme(scheme, keys, w)
                us = (time.time() - t0) * 1e6
                out[(z, scheme, w)] = m.memory_overhead_norm
                rep.add(f"fig11_mem_vs_fg/zf{z}/{scheme}/w{w}", us,
                        round(m.memory_overhead_norm, 3))
    fish128 = max(v for (z, s, w), v in out.items()
                  if s == "fish" and w == 128)
    sg128 = min(v for (z, s, w), v in out.items() if s == "sg" and w == 128)
    rep.add("fig11/fish_worst_mem_at_128", 0.0, round(fish128, 3))
    return {"fish_worst_mem_128": fish128, "sg_best_mem_128": sg128}
