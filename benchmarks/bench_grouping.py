"""Grouping-engine throughput: batched vs per-tuple reference (ISSUE 1).

Times every scheme through both simulator engines on the AM proxy stream and
emits ``artifacts/BENCH_grouping.json`` — tuples/sec per scheme per engine
plus the speedup — so later PRs have a perf trajectory to regress against.
"""

from __future__ import annotations

import json
import os
import time

from repro.topology import SCHEME_CONFIGS

from .common import ARTIFACT_DIR, Reporter, SCHEMES, am_proxy_keys, run_scheme

_WORKERS = 32


def run(rep: Reporter) -> dict:
    keys = am_proxy_keys()
    out = {"n_tuples": int(len(keys)), "workers": _WORKERS, "schemes": {}}
    SCHEME_CONFIGS["fish"]().build(_WORKERS)  # warm the consistent-hash ring
    # cache so neither timed window pays one-off SHA-1 ring construction
    for scheme in SCHEMES:
        t0 = time.time()
        _, m_b = run_scheme(scheme, keys, _WORKERS, simulator="batched")
        t_batched = time.time() - t0
        t0 = time.time()
        _, m_r = run_scheme(scheme, keys, _WORKERS, simulator="reference")
        t_reference = time.time() - t0
        row = {
            "batched_tps": round(len(keys) / t_batched, 1),
            "reference_tps": round(len(keys) / t_reference, 1),
            "speedup": round(t_reference / t_batched, 2),
            "batched_exec_time": round(m_b.execution_time, 4),
            "reference_exec_time": round(m_r.execution_time, 4),
        }
        out["schemes"][scheme] = row
        rep.add(f"grouping_tps/{scheme}/batched", t_batched * 1e6,
                row["batched_tps"])
        rep.add(f"grouping_tps/{scheme}/reference", t_reference * 1e6,
                row["reference_tps"])
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, "BENCH_grouping.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    rep.add("grouping_tps/artifact", 0.0, path)
    return out
