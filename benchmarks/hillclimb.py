"""§Perf hillclimb driver: re-lower the three chosen cells after each
optimization and record tagged artifacts (benchmarks/artifacts/dryrun/).

    PYTHONPATH=src python -m benchmarks.hillclimb --iter rs|scatter|headroom
"""
import argparse
import dataclasses
import json

CELLS = [
    ("kimi-k2-1t-a32b", "train_4k"),
    ("recurrentgemma-9b", "train_4k"),
    ("deepseek-v2-lite-16b", "train_4k"),
]


def show(r):
    rf = r.get("roofline", {})
    ma = r["memory_analysis"]
    print(f"{r['arch']:22s} {r['shape']} tag-done: "
          f"flops={r.get('flops_global', 0):.3e} "
          f"coll/dev={r['collective_bytes_total']/2**30:.3f}GiB "
          f"compute_s={rf.get('compute_s', 0):.4f} "
          f"coll_s={rf.get('collective_s', 0):.4f} "
          f"temp={ma['temp_size_in_bytes']/2**30:.2f}GiB", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iter", required=True,
                    choices=["rs", "scatter", "headroom", "gradrs"])
    ap.add_argument("--cells", default=None, help="comma list arch:shape")
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell
    from repro.configs import get_config

    cells = CELLS
    if args.cells:
        cells = [tuple(c.split(":")) for c in args.cells.split(",")]

    for arch, shape in cells:
        cfg = get_config(arch)
        tag = args.iter
        if args.iter in ("rs", "gradrs"):
            pass  # global change, config untouched
        elif args.iter == "scatter":
            if cfg.moe is None:
                continue
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, dispatch_impl="scatter"))
        elif args.iter == "headroom":
            if cfg.moe is None:
                continue
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, dispatch_impl="scatter",
                                             hot_headroom=1.25))
        if args.iter == "gradrs" and cfg.moe is not None:
            # carry the previous winners forward
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, dispatch_impl="scatter",
                                             hot_headroom=1.25))
        r = run_cell(arch, shape, cfg_override=cfg, extra_tag=tag)
        show(r)


if __name__ == "__main__":
    main()
