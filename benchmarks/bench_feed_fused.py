"""Device-resident fused feed hot path (ISSUE 6): per-feed dispatch cost.

Measures what the fused engine is for — collapsing the per-feed Python
routing/FIFO/state work into one jitted device launch — against the host
batched engine on the same workload: a 32-worker windowed-aggregation
stage (``WindowOp(agg="sum", value="payload")``, window = 16k tuples)
fed record batches of 256 → 16k tuples.

Per (scheme, batch size) the artifact records steady-state per-feed
wall-clock p50/p99 (feeds after the first — the first feed pays jit
tracing and device-table allocation), the fused-vs-batched speedup, and
the device dispatches per steady-state feed (the ISSUE 6 acceptance
evidence: exactly 1 when feed boundaries land on pane boundaries and no
events fire).  ``speedup_p50`` is the median of *paired* per-rep ratios
(each rep times one fused and one batched session back-to-back, so
slow machine-speed drift cancels out of the quotient);
``speedup_pooled`` is the cruder ratio of pooled medians.

Equivalence is asserted, not assumed: both engines must route every
tuple, and the merged windows must match bit-for-bit (keyed state is
routed-stream-exact in every scheme).

Emits ``artifacts/BENCH_feed_fused.json``.  Module-level constants are
the CI-scale knobs (see .github/workflows/ci.yml).

The run ends with the ISSUE 9 telemetry-overhead guard: paired fused
sessions with telemetry off/on at the largest batch size, asserting the
enabled steady-state p50 stays within ``OBS_OVERHEAD_BUDGET`` (and that
enabling changes no dispatch count).  The paired ratio lands in
``artifacts/BENCH_obs_overhead.json``.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.data.synthetic import zipf_time_evolving
from repro.state import WindowOp
from repro.topology import (Edge, SimulatorEngine, Source, Stage, Topology,
                            config_for)

from .common import ARTIFACT_DIR, Reporter

N_TUPLES = 65_536  # divisible by every batch size: uniform steady feeds
N_KEYS = 4_000
Z = 1.4
ARRIVAL_RATE = 20_000.0
WORKERS = 32
WINDOW = 16_384
BATCH_SIZES = (256, 1_024, 4_096, 16_384)
SCHEMES = ("sg", "fg", "pkg", "fish")
REPS = 2  # sessions per (scheme, batch) — steady-state samples pool across
MIN_STEADY = 48  # sample floor per engine: p50 must survive machine drift
# ISSUE 9 overhead contract: enabled/disabled steady-state p50 ratio bound,
# measured on paired back-to-back sessions at the largest batch size
OBS_OVERHEAD_BUDGET = 1.05
OBS_REPS = 6
OBS_BATCH = 16_384
OBS_SCHEME = "fish"


def _reps(bs: int) -> int:
    """Alternating sessions per engine at one batch size.  Large batches
    have few feeds per session, so they run more sessions to keep the
    pooled steady-state sample count (and the p50's noise immunity)
    roughly constant across batch sizes."""
    steady = max(N_TUPLES // bs - 1, 1)
    return max(REPS, -(-MIN_STEADY // steady))


def _topology(scheme) -> Topology:
    return Topology(
        name=f"fused-{scheme}",
        stages=(Stage("agg", parallelism=WORKERS,
                      operator=WindowOp(agg="sum", value="payload",
                                        size=WINDOW)),),
        edges=(Edge("source", "agg", config_for(scheme)),),
    )


def _feed_loop(mode: str, scheme: str, src: Source, bs: int, telemetry=None):
    eng = SimulatorEngine(mode=mode)
    session = eng.open(_topology(scheme), arrival_rate=ARRIVAL_RATE,
                       telemetry=telemetry)
    per_feed = []
    for batch in src.iter_batches(batch_size=bs):
        t0 = time.time()
        session.feed(batch)
        per_feed.append(time.time() - t0)
    report = session.close()
    return per_feed, report


def run(rep: Reporter) -> dict:
    keys = zipf_time_evolving(N_TUPLES, num_keys=N_KEYS, z=Z, seed=0)
    values = np.random.default_rng(1).integers(
        1, 100, keys.shape[0]).astype(np.int64)
    n = int(keys.shape[0])
    src = Source(keys, arrival_rate=ARRIVAL_RATE, values=values)
    out = {"n_tuples": n, "n_keys": N_KEYS, "workers": WORKERS,
           "window": WINDOW, "schemes": {}}

    for scheme in SCHEMES:
        out["schemes"][scheme] = {}
        for bs in BATCH_SIZES:
            steady_f, steady_b, ratios = [], [], []
            first_feed = None
            for it in range(_reps(bs)):
                t_fused, rf = _feed_loop("fused", scheme, src, bs)
                t_batch, rb = _feed_loop("batched", scheme, src, bs)
                sf_i = t_fused[1:] or t_fused
                sb_i = t_batch[1:] or t_batch
                steady_f += sf_i
                steady_b += sb_i
                # paired per-rep ratio: the two sessions run back-to-back,
                # so machine-speed drift (large on shared hosts, and slower
                # than a rep) cancels out of the quotient
                ratios.append(float(np.median(sb_i))
                              / max(float(np.median(sf_i)), 1e-12))
                if it:
                    continue
                first_feed = t_fused[0]
                ef, eb = rf.edges[0], rb.edges[0]
                # both engines routed the whole stream; keyed window state
                # is routed-stream-exact, so merged windows match exactly
                assert ef.n_tuples == eb.n_tuples == n, (scheme, bs)
                assert (rf.state["agg"]["merged"]
                        == rb.state["agg"]["merged"]), (scheme, bs)
                n_feeds = len(t_fused)
                # feed boundaries divide the window, so every steady-state
                # feed is exactly one event-free segment → one device launch
                assert ef.dispatches == n_feeds, (scheme, bs, ef.dispatches)
                assert eb.dispatches == 0, (scheme, bs)
            sf = np.asarray(steady_f)
            sb = np.asarray(steady_b)
            p50_f, p50_b = float(np.median(sf)), float(np.median(sb))
            row = {
                "batch_size": bs,
                "n_feeds": n_feeds,
                "fused_ms_p50": p50_f * 1e3,
                "fused_ms_p99": float(np.percentile(sf, 99)) * 1e3,
                "batched_ms_p50": p50_b * 1e3,
                "batched_ms_p99": float(np.percentile(sb, 99)) * 1e3,
                "first_feed_ms": first_feed * 1e3,
                "dispatches_per_feed": ef.dispatches / n_feeds,
                "speedup_p50": float(np.median(ratios)),
                "speedup_pooled": p50_b / max(p50_f, 1e-12),
                "fused_tuples_per_s": bs / max(p50_f, 1e-12),
            }
            out["schemes"][scheme][str(bs)] = row
            rep.add(f"feed_fused/{scheme}/b{bs}", p50_f * 1e6,
                    f"{row['speedup_p50']:.2f}x batched, "
                    f"{row['dispatches_per_feed']:.0f} dispatch/feed")

    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, "BENCH_feed_fused.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    rep.add("feed_fused/artifact", 0.0, path)
    out["obs_overhead"] = _obs_overhead(rep, src)
    return out


def _obs_overhead(rep: Reporter, src: Source) -> dict:
    """ISSUE 9 overhead guard: telemetry-on vs telemetry-off fused
    sessions, paired back-to-back per rep so machine-speed drift cancels
    out of each ratio.  The artifact is written *before* the assert fires
    so a budget breach still leaves its evidence on disk."""
    from repro.obs.telemetry import Telemetry

    steady_off, steady_on, ratios = [], [], []
    for it in range(OBS_REPS):
        t_off, r_off = _feed_loop("fused", OBS_SCHEME, src, OBS_BATCH)
        t_on, r_on = _feed_loop("fused", OBS_SCHEME, src, OBS_BATCH,
                                telemetry=Telemetry(enabled=True))
        s_off = t_off[1:] or t_off
        s_on = t_on[1:] or t_on
        steady_off += s_off
        steady_on += s_on
        ratios.append(float(np.median(s_on))
                      / max(float(np.median(s_off)), 1e-12))
        if it:
            continue
        ef_off, ef_on = r_off.edges[0], r_on.edges[0]
        # instrumentation observes, never reshapes: the launch count and
        # the routed stream are unchanged by turning telemetry on
        assert ef_on.dispatches == ef_off.dispatches, (
            ef_on.dispatches, ef_off.dispatches)
        assert ef_on.n_tuples == ef_off.n_tuples
        assert r_on.state["agg"]["merged"] == r_off.state["agg"]["merged"]
    ratio = float(np.median(ratios))
    row = {
        "scheme": OBS_SCHEME,
        "batch_size": OBS_BATCH,
        "reps": OBS_REPS,
        "budget": OBS_OVERHEAD_BUDGET,
        "disabled_ms_p50": float(np.median(steady_off)) * 1e3,
        "enabled_ms_p50": float(np.median(steady_on)) * 1e3,
        "overhead_ratio_p50": ratio,
        "ratios": ratios,
    }
    path = os.path.join(ARTIFACT_DIR, "BENCH_obs_overhead.json")
    with open(path, "w") as f:
        json.dump(row, f, indent=2, sort_keys=True)
    rep.add(f"feed_fused/obs_overhead/b{OBS_BATCH}",
            row["enabled_ms_p50"] * 1e3,
            f"{ratio:.3f}x disabled (budget {OBS_OVERHEAD_BUDGET}x)")
    assert ratio <= OBS_OVERHEAD_BUDGET, (
        f"telemetry overhead {ratio:.3f}x exceeds "
        f"{OBS_OVERHEAD_BUDGET}x budget ({path})")
    return row
