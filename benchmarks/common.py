"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import csv
import io
import os
import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import simulate_edge
from repro.data.synthetic import piecewise_zipf, zipf_time_evolving
from repro.topology import (Edge, SimulatorEngine, Source, Stage, Topology,
                            build_grouper, config_for)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")

# CPU-friendly scale: the batched engine is O(tuples) NumPy work; the paper's
# 50M-tuple runs use identical code at scale=1 (see data/synthetic.py Table-2
# proxies).
N_TUPLES = 30_000
N_KEYS = 3_000
WORKERS = (16, 32, 64, 128)
SCHEMES = ("fg", "pkg", "sg", "dc", "wc", "fish")


def run_scheme(scheme, keys, workers: int, capacities=None,
               arrival_rate: float = 20_000.0, simulator: str = "batched",
               **kw):
    """Route ``keys`` through one grouped edge of ``scheme`` (a scheme name
    or a typed :class:`~repro.topology.SchemeConfig`); ``simulator`` picks
    the batched engine (default — ISSUE 1) or the per-tuple ``"reference"``
    oracle.  Returns ``(grouper, StreamMetrics)``."""
    if simulator not in ("batched", "reference"):
        raise ValueError(f"unknown simulator {simulator!r}")
    if capacities is None:
        capacities = np.full(workers, 0.9 * workers / arrival_rate)
    # no oracle capacities for the grouper: capacity-aware schemes discover
    # P_w through the sampling hook (matches the legacy make_grouper path)
    g = build_grouper(scheme, workers)
    res = simulate_edge(g, keys, mode=simulator, capacities=capacities,
                        arrival_rate=arrival_rate, **kw)
    return g, res.metrics


def run_edge(scheme, keys, workers: int,
             arrival_rate: float = 20_000.0, simulator: str = "batched"):
    """One grouped edge through the unified engine protocol (ISSUE 3):
    builds a single-edge :class:`Topology` and runs it on
    :class:`SimulatorEngine`.  Returns the :class:`EdgeReport`."""
    spec = scheme if not isinstance(scheme, str) else config_for(scheme)
    topo = Topology(name=f"edge-{spec.scheme}",
                    stages=(Stage("worker", parallelism=workers),),
                    edges=(Edge("source", "worker", spec),))
    rep = SimulatorEngine(mode=simulator).run(
        topo, Source(np.asarray(keys), arrival_rate=arrival_rate))
    return rep.edge("worker")


def am_proxy_keys(seed=0):
    return piecewise_zipf(N_TUPLES, N_KEYS, z=1.2, phases=6, seed=seed)


def mt_proxy_keys(seed=1):
    return piecewise_zipf(N_TUPLES, N_KEYS, z=1.1, phases=8, seed=seed)


def zf_keys(z: float, seed=2):
    return zipf_time_evolving(N_TUPLES, num_keys=N_KEYS, z=z,
                              flip_head=N_KEYS // 3, seed=seed)


class Reporter:
    """Collects ``name,us_per_call,derived`` rows (benchmarks/run.py CSV).

    Failures are recorded separately from measurements: an erroring module
    must never contribute a zero-valued row to the CSV that downstream
    artifact parsing would read as a measurement.  ``csv()`` emits
    measurements only; ``failure_summary()`` renders the failures (run.py
    prints it to stderr and sets the exit code).
    """

    def __init__(self):
        self.rows: List[Dict] = []
        self.failures: List[Dict] = []
        self._trace = None  # TraceWriter for the module now running, if any

    def timeit(self, name: str, fn: Callable, derived_fn=None):
        t0 = time.time()
        out = fn()
        us = (time.time() - t0) * 1e6
        derived = derived_fn(out) if derived_fn else out
        self.rows.append({"name": name, "us_per_call": round(us, 1),
                          "derived": derived})
        return out

    def add(self, name: str, us: float, derived):
        self.rows.append({"name": name, "us_per_call": round(us, 1),
                          "derived": derived})

    def attach_trace(self, writer) -> None:
        """Bind the currently-recording :class:`~repro.obs.export.TraceWriter`
        so a failing module's trace gets sealed instead of truncated."""
        self._trace = writer

    def add_failure(self, name: str, error: BaseException):
        self.failures.append({"name": name,
                              "error": f"{type(error).__name__}: {error}"})
        # ISSUE 9 bugfix: a module that dies mid-run must flush its partial
        # trace as *valid* JSON — whatever the bundle collected before the
        # crash is written out, then abort() seals the event array and
        # renames the tmp file into place with an ``aborted`` stamp
        if self._trace is not None and not self._trace.closed:
            try:
                from repro.obs import telemetry
                tel = telemetry.get_telemetry()
                if tel.enabled:
                    self._trace.write_telemetry(tel)
            except Exception:
                pass  # the seal below must happen even if the flush can't
            self._trace.abort(f"{name}: {type(error).__name__}: {error}")
        self._trace = None

    def csv(self) -> str:
        buf = io.StringIO()
        w = csv.DictWriter(buf, fieldnames=["name", "us_per_call", "derived"])
        w.writeheader()
        for r in self.rows:
            w.writerow(r)
        return buf.getvalue()

    def failure_summary(self) -> str:
        return "\n".join(f"FAILED {f['name']}: {f['error']}"
                         for f in self.failures)
