"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import csv
import io
import os
import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import make_grouper, simulate_stream, simulate_stream_reference
from repro.data.synthetic import piecewise_zipf, zipf_time_evolving

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")

# CPU-friendly scale: the batched engine is O(tuples) NumPy work; the paper's
# 50M-tuple runs use identical code at scale=1 (see data/synthetic.py Table-2
# proxies).
N_TUPLES = 30_000
N_KEYS = 3_000
WORKERS = (16, 32, 64, 128)
SCHEMES = ("fg", "pkg", "sg", "dc", "wc", "fish")


def run_scheme(scheme: str, keys, workers: int, capacities=None,
               arrival_rate: float = 20_000.0, simulator: str = "batched",
               **kw):
    """Route ``keys`` through ``scheme``; ``simulator`` picks the batched
    engine (default — ISSUE 1) or the per-tuple ``"reference"`` oracle."""
    if simulator not in ("batched", "reference"):
        raise ValueError(f"unknown simulator {simulator!r}")
    g = make_grouper(scheme, workers)
    if capacities is None:
        capacities = np.full(workers, 0.9 * workers / arrival_rate)
    sim = simulate_stream if simulator == "batched" else simulate_stream_reference
    m = sim(g, keys, capacities=capacities, arrival_rate=arrival_rate, **kw)
    return g, m


def am_proxy_keys(seed=0):
    return piecewise_zipf(N_TUPLES, N_KEYS, z=1.2, phases=6, seed=seed)


def mt_proxy_keys(seed=1):
    return piecewise_zipf(N_TUPLES, N_KEYS, z=1.1, phases=8, seed=seed)


def zf_keys(z: float, seed=2):
    return zipf_time_evolving(N_TUPLES, num_keys=N_KEYS, z=z,
                              flip_head=N_KEYS // 3, seed=seed)


class Reporter:
    """Collects ``name,us_per_call,derived`` rows (benchmarks/run.py CSV)."""

    def __init__(self):
        self.rows: List[Dict] = []

    def timeit(self, name: str, fn: Callable, derived_fn=None):
        t0 = time.time()
        out = fn()
        us = (time.time() - t0) * 1e6
        derived = derived_fn(out) if derived_fn else out
        self.rows.append({"name": name, "us_per_call": round(us, 1),
                          "derived": derived})
        return out

    def add(self, name: str, us: float, derived):
        self.rows.append({"name": name, "us_per_call": round(us, 1),
                          "derived": derived})

    def csv(self) -> str:
        buf = io.StringIO()
        w = csv.DictWriter(buf, fieldnames=["name", "us_per_call", "derived"])
        w.writeheader()
        for r in self.rows:
            w.writerow(r)
        return buf.getvalue()
