"""Max sustainable load at a fixed p99 SLO, per grouping scheme (ISSUE 8).

The headline open-loop experiment: a fixed worker pool (load-independent
per-tuple cost, so aggregate capacity ``CAP`` does not move with offered
load), swept over offered-load fractions of that capacity under two
arrival regimes —

* **steady** — constant rate, steady Zipf keys;
* **drift_flash** — hot-key flip at mid-run *plus* a 2× flash crowd —
  the paper's time-evolving adversary, where load balance must be
  re-won while the queue is already growing.

A load point is **sustainable** for a scheme when the run sheds nothing
and total p99 (queueing delay + service latency, billed per tuple by the
open-loop driver) stays within ``SLO_P99``.  ``max_sustainable_frac`` is
the highest swept fraction that passes; the JSON records whether FISH
sustains at least the best baseline under drift (the ISSUE-8 acceptance
line).

Two demonstration blocks ride along:

* **overload** — offered ≈ 2× capacity through a *bounded* ingress queue
  (shed policy + backpressure) on both the simulator and the
  arrival-paced serving engine; the admission identity
  ``offered == fed + shed_ingress + residual`` is checked exactly, and
  the serving run also exercises the engine-level bounded replica queues
  (``shed_engine``).
* **autoscale** — a flash crowd against the p99 autoscaler with keyed
  window state attached: membership events stream through the elastic
  pool and state migration is billed to the destination workers' clocks
  (``migration_stall`` > 0 whenever the scaler acted).

Emits ``artifacts/BENCH_slo.json``.  Module-level knobs (``HORIZON``,
``FRACS``, ``N_KEYS``) are the CI-scale levers (see
.github/workflows/ci.yml).
"""

from __future__ import annotations

import json
import os
import time

from repro.scenarios import OpenLoopScenario, run_open_loop_scenario
from repro.state import WindowOp

from .common import ARTIFACT_DIR, Reporter, SCHEMES

WORKERS = 8
CAP = 4_000.0          # aggregate pool capacity, tuples/s (cost = W/CAP each)
HORIZON = 4.0          # seconds of arrivals per run
TICK = 0.05            # arrival tick = one feed
N_KEYS = 1_024
FRACS = (0.5, 0.6, 0.7, 0.8, 0.9)   # offered load as a fraction of CAP
SLO_P99 = 0.2          # seconds of *total* latency (100× the per-tuple cost)
BASELINES = tuple(s for s in SCHEMES if s != "fish")


def _scenario(variant: str, frac: float, **kw) -> OpenLoopScenario:
    """One swept load point: rate = frac·CAP with utilization = frac keeps
    the per-worker cost at W/CAP for every point — the pool never gets
    faster just because more load is offered."""
    drift = variant == "drift_flash"
    return OpenLoopScenario(
        f"slo_{variant}", workers=WORKERS, rate=frac * CAP,
        utilization=frac, horizon=HORIZON, tick=TICK, num_keys=N_KEYS,
        z=1.4 if drift else 1.2,
        flip_time=0.5 * HORIZON if drift else None,
        flash=(0.45 * HORIZON, 0.2 * HORIZON, 2.0) if drift else None,
        **kw)


def _sweep(rep: Reporter) -> dict:
    out = {}
    for variant in ("steady", "drift_flash"):
        per_scheme = {}
        for scheme in SCHEMES:
            points = []
            best = 0.0
            for frac in FRACS:
                # defer policy + unbounded-in-practice queue: the sweep
                # measures latency under load, not the admission policy —
                # nothing may be lost, overload must show up as delay
                ol = _scenario(variant, frac, queue_capacity=1_000_000,
                               policy="defer", backpressure=None)
                t0 = time.time()
                r = run_open_loop_scenario(ol, scheme, engine="batched",
                                           drain=True)
                us = (time.time() - t0) * 1e6
                ok = (r["shed"] == 0 and r["residual"] == 0
                      and r["total_latency_p99"] is not None
                      and r["total_latency_p99"] <= SLO_P99)
                if ok and frac > best:
                    best = frac
                points.append({
                    "frac": frac, "offered": r["offered"],
                    "total_latency_p99": r["total_latency_p99"],
                    "queue_delay_p99": r["queue_delay_p99"],
                    "service_latency_p99": r["latency_p99"],
                    "shed": r["shed"], "sustainable": ok,
                })
                rep.add(f"slo/{variant}/{scheme}/frac={frac}", us,
                        f"p99={r['total_latency_p99']:.4f} ok={ok}")
            per_scheme[scheme] = {"points": points,
                                  "max_sustainable_frac": best}
        out[variant] = per_scheme
    drift = out["drift_flash"]
    best_baseline = max(drift[s]["max_sustainable_frac"] for s in BASELINES)
    out["fish_sustains_best_drift"] = (
        drift["fish"]["max_sustainable_frac"] >= best_baseline)
    out["best_baseline_drift_frac"] = best_baseline
    rep.add("slo/fish_vs_best_baseline", 0.0,
            f"fish={drift['fish']['max_sustainable_frac']} "
            f"baseline={best_baseline} "
            f"ok={out['fish_sustains_best_drift']}")
    return out


def _overload(rep: Reporter) -> dict:
    """Offered ≈ 2× capacity through a bounded queue: the ingress queue
    must stay bounded, the shed must be billed, and the identity must
    close exactly — on both engines."""
    out = {}
    cap = max(int(0.05 * 2.0 * CAP * HORIZON), 64)
    ol = _scenario("steady", 2.0, queue_capacity=cap, policy="shed",
                   backpressure=0.25)
    for engine in ("batched", "serving"):
        t0 = time.time()
        r = run_open_loop_scenario(ol, "fish", engine=engine, drain=True,
                                   ticks_per_second=CAP / 4.0,
                                   max_queue_per_replica=32)
        us = (time.time() - t0) * 1e6
        out[engine] = {k: r[k] for k in (
            "offered", "fed", "shed", "shed_ingress", "shed_engine",
            "deferred", "residual", "identity_ok", "queue_depth_peak",
            "queue_delay_avg", "queue_delay_p99")}
        out[engine]["queue_capacity"] = cap
        if not r["identity_ok"]:
            raise AssertionError(
                f"open-loop admission identity broken ({engine}): {r}")
        if r["shed"] <= 0:
            raise AssertionError(
                f"2x-capacity overload shed nothing ({engine}): {r}")
        rep.add(f"slo/overload/{engine}", us,
                f"shed={r['shed']}/{r['offered']} "
                f"depth_peak={r['queue_depth_peak']} identity=ok")
    return out


def _autoscale(rep: Reporter) -> dict:
    """Flash crowd against the p99 autoscaler with keyed window state:
    scale-out must fire, and the state migration it forces must be billed
    to the engine clock (migration_stall > 0)."""
    ol = OpenLoopScenario(
        "slo_autoscale", workers=max(WORKERS // 2, 2), rate=0.7 * CAP / 2,
        utilization=0.7, horizon=HORIZON, tick=TICK, num_keys=N_KEYS,
        flash=(0.25 * HORIZON, 0.5 * HORIZON, 2.5),
        queue_capacity=1_000_000, policy="defer", backpressure=None,
        slo_p99=0.08, max_workers=WORKERS * 2)
    t0 = time.time()
    # any key-owning scheme works here; shuffle grouping ("sg") would not —
    # scattered keys have no owner, so membership changes migrate ~nothing
    r = run_open_loop_scenario(
        ol, "fish", engine="batched", drain=True,
        migration_cost_per_byte=1e-5,
        window=WindowOp("count", size=max(int(ol.rate * HORIZON), 1)))
    us = (time.time() - t0) * 1e6
    out = {k: r[k] for k in (
        "offered", "fed", "identity_ok", "total_latency_p99",
        "autoscale_events", "workers_final", "migration_stall")}
    if not out["autoscale_events"]:
        raise AssertionError("flash crowd triggered no autoscale actions")
    if not out["migration_stall"] > 0.0:
        raise AssertionError("autoscale membership changes billed no "
                             "migration stall despite keyed window state")
    rep.add("slo/autoscale", us,
            f"events={len(out['autoscale_events'])} "
            f"workers={len(out['workers_final'])} "
            f"stall={out['migration_stall']:.5f}s")
    return out


def run(rep: Reporter) -> dict:
    out = {"workers": WORKERS, "capacity": CAP, "horizon": HORIZON,
           "tick": TICK, "n_keys": N_KEYS, "fracs": list(FRACS),
           "slo_p99": SLO_P99,
           "sweep": _sweep(rep),
           "overload": _overload(rep),
           "autoscale": _autoscale(rep)}
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, "BENCH_slo.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    rep.add("slo/artifact", 0.0, path)
    return out
