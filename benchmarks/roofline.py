"""§Roofline: assemble the per-(arch × shape) roofline table from the
dry-run artifacts (launch/dryrun.py) + analytic MODEL_FLOPS.

    compute term    = HLO_FLOPs / (chips × 197 TFLOP/s)
    memory term     = HLO_bytes / (chips × 819 GB/s)       [unfused bound]
    collective term = per-device collective bytes / 50 GB/s

MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill, decode) with N = active
matmul params; the MODEL_FLOPS / HLO_FLOPs ratio exposes remat recompute,
MoE one-hot-dispatch waste, and attention's quadratic term.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

from repro.configs import SHAPES, get_config, get_shape, list_archs
from repro.configs.base import ModelConfig, ShapeConfig

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")
PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9


# ---------------------------------------------------------------------------
# Analytic matmul-parameter counts (per family)
# ---------------------------------------------------------------------------


def _attn_params(cfg: ModelConfig) -> int:
    d, hq, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return d * hq * dh + 2 * d * hkv * dh + hq * dh * d


def _mla_params(cfg: ModelConfig) -> int:
    m, d, h = cfg.mla, cfg.d_model, cfg.num_heads
    return (d * h * (m.qk_nope_dim + m.qk_rope_dim)
            + d * (m.kv_lora_rank + m.qk_rope_dim)
            + m.kv_lora_rank * h * (m.qk_nope_dim + m.v_head_dim)
            + h * m.v_head_dim * d)


def _mlp_params(cfg: ModelConfig, f: Optional[int] = None) -> int:
    f = f or cfg.d_ff
    mult = 3 if cfg.mlp_kind in ("swiglu", "geglu") else 2
    return mult * cfg.d_model * f


def matmul_params(cfg: ModelConfig) -> Dict[str, float]:
    """Returns {'active': N_active, 'total': N_total} matmul params."""
    d = cfg.d_model
    pv = -(-cfg.vocab_size // 128) * 128
    head = d * pv  # tied or not, the unembed matmul runs once

    if cfg.ssm is not None:
        s = cfg.ssm
        d_inner = s.expand * d
        n_heads = d_inner // s.head_dim
        per_layer = d * (2 * d_inner + 2 * s.n_groups * s.d_state + n_heads) \
            + d_inner * d
        n = cfg.num_layers * per_layer + head
        return {"active": n, "total": n}

    if cfg.rglru is not None:
        rg = cfg.rglru
        w = rg.lru_width or d
        rec = 2 * d * w + 2 * w * (w // rg.gate_blocks) + w * d \
            + _mlp_params(cfg)
        attn = _attn_params(cfg) + _mlp_params(cfg)
        n_groups = cfg.num_layers // rg.attention_every
        n_rec = cfg.num_layers - n_groups
        n = n_rec * rec + n_groups * attn + head
        return {"active": n, "total": n}

    if cfg.encoder_layers:
        dec = (_attn_params(cfg) * 2 + _mlp_params(cfg)) * cfg.num_layers
        enc = (_attn_params(cfg) + _mlp_params(cfg)) * cfg.encoder_layers
        # encoder runs on encoder_seq tokens; fold via the seq ratio at use
        return {"active": dec + head, "total": dec + enc + head,
                "encoder": enc}

    attn = _mla_params(cfg) if cfg.mla else _attn_params(cfg)
    if cfg.moe is not None:
        m = cfg.moe
        expert = 3 * d * m.d_ff_expert
        shared = 3 * d * (m.d_ff_expert * m.shared_experts)
        router = d * m.num_experts
        moe_layers = cfg.num_layers - m.first_dense_layers
        dense_l = m.first_dense_layers
        active = (cfg.num_layers * attn
                  + moe_layers * (m.top_k * expert + shared + router)
                  + dense_l * _mlp_params(cfg)
                  + head)
        total = (cfg.num_layers * attn
                 + moe_layers * (m.num_experts * expert + shared + router)
                 + dense_l * _mlp_params(cfg)
                 + head)
        return {"active": active, "total": total}

    per_layer = attn + _mlp_params(cfg)
    n = cfg.num_layers * per_layer + head
    return {"active": n, "total": n}


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N_active·D (train) / 2·N_active·D (prefill/decode)."""
    counts = matmul_params(cfg)
    n_act = counts["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        f = 6.0 * n_act * tokens
        if "encoder" in counts:
            f += 6.0 * counts["encoder"] * shape.global_batch * cfg.encoder_seq
        return f
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        f = 2.0 * n_act * tokens
        if "encoder" in counts:
            f += 2.0 * counts["encoder"] * shape.global_batch * cfg.encoder_seq
        return f
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch


def hbm_traffic_bytes(cfg: ModelConfig, shape: ShapeConfig, artifact: Dict
                      ) -> float:
    """Analytic per-device HBM traffic per step (lower-bound model).

    train:   params×(3 reads: fwd+bwd+remat, 1 write) + m,v × (read+write)
             + grads ×(write+read) + saved residual-stream activations ×2
    prefill: params×1 + activations×4 + cache write
    decode:  params×1 + KV cache read+write            (the classic
             decode memory wall)
    The XLA-unfused 'bytes accessed' is reported alongside as an upper bound.
    """
    dev = artifact["devices"]
    p = artifact["param_bytes_global"] / dev
    state_ratio = {"float32": 2.0, "bfloat16": 1.0}.get(cfg.opt_state_dtype, 1.0)
    m = p * state_ratio
    v = 0.05 * m if cfg.opt_factored else m
    g = p  # bf16 grads, params-sized

    tokens_dev = shape.global_batch * shape.seq_len / dev
    act = tokens_dev * cfg.d_model * 2 * cfg.num_layers  # saved h, bf16

    cache = (artifact.get("memory_analysis", {}) or {}).get(
        "argument_size_in_bytes") or 0
    if shape.kind == "train":
        return 4 * p + 2 * m + 2 * v + 2 * g + 2 * act
    if shape.kind == "prefill":
        return p + 4 * act
    # decode: params once + cache r/w (cache dominates the argument bytes)
    return p + 2 * max(cache - p, 0)


# ---------------------------------------------------------------------------
# Table assembly
# ---------------------------------------------------------------------------


def load_artifacts(tag: str = "singlepod") -> Dict:
    out = {}
    for path in glob.glob(os.path.join(ART, f"*_{tag}.json")):
        with open(path) as f:
            r = json.load(f)
        out[(r["arch"], r["shape"])] = r
    return out


def bottleneck_advice(dominant: str, arch: str, shape: str) -> str:
    return {
        "compute": "raise arithmetic efficiency: cut remat recompute / "
                   "one-hot dispatch FLOPs (scatter dispatch), fuse matmuls",
        "memory": "cut HBM traffic: larger fusion blocks, bf16 intermediates"
                  ", fewer saved residuals (deeper remat)",
        "collective": "reshard: shrink per-layer weight gathers (bigger "
                      "grad-accum amortisation), overlap a2a with expert "
                      "compute, reduce-scatter instead of all-reduce",
    }[dominant]


def build_rows(tag: str = "singlepod"):
    arts = load_artifacts(tag)
    rows = []
    for arch in list_archs():
        cfg = get_config(arch)
        for sname in SHAPES:
            r = arts.get((arch, sname))
            if r is None:
                continue
            if r.get("status") == "skipped":
                rows.append({"arch": arch, "shape": sname,
                             "status": "skipped", "reason": r["reason"]})
                continue
            shape = get_shape(sname)
            mf = model_flops(cfg, shape)
            rf = r.get("roofline") or {}
            mem_bytes = hbm_traffic_bytes(cfg, shape, r)
            terms = {
                "compute": rf.get("compute_s", 0.0) or 0.0,
                "memory": mem_bytes / HBM_BW,
                "collective": rf.get("collective_s", 0.0) or 0.0,
            }
            dominant = max(terms, key=terms.get)
            hlo = r.get("flops_global", 0.0)
            rows.append({
                "arch": arch, "shape": sname, "status": "ok",
                "devices": r["devices"],
                "compute_s": terms["compute"],
                "memory_s": terms["memory"],
                "memory_s_unfused_ub": rf.get("memory_s", 0.0) or 0.0,
                "collective_s": terms["collective"],
                "dominant": dominant,
                "model_flops": mf,
                "hlo_flops": hlo,
                "useful_ratio": (mf / hlo) if hlo else None,
                "advice": bottleneck_advice(dominant, arch, sname),
                "temp_gib": (r["memory_analysis"].get("temp_size_in_bytes")
                             or 0) / 2**30,
                "args_gib": (r["memory_analysis"].get("argument_size_in_bytes")
                             or 0) / 2**30,
            })
    return rows


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| MODEL_FLOPS | useful ratio | args GiB | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — | — | — |")
            continue
        ur = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "—"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['model_flops']:.3e} | {ur} | "
            f"{r['args_gib']:.2f} | {r['temp_gib']:.2f} |")
    return hdr + "\n".join(lines) + "\n"


def csv_table(rows) -> str:
    import io, csv as _csv

    buf = io.StringIO()
    cols = ["arch", "shape", "status", "compute_s", "memory_s",
            "memory_s_unfused_ub", "collective_s", "dominant", "model_flops",
            "hlo_flops", "useful_ratio", "args_gib", "temp_gib", "advice"]
    w = _csv.DictWriter(buf, fieldnames=cols, extrasaction="ignore")
    w.writeheader()
    for r in rows:
        w.writerow(r)
    return buf.getvalue()


def run(rep=None) -> str:
    rows = build_rows()
    md = markdown_table(rows)
    out_path = os.path.join(os.path.dirname(__file__), "artifacts",
                            "roofline_table.md")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        f.write(md)
    if rep is not None:
        for r in rows:
            if r["status"] == "ok":
                rep.add(f"roofline/{r['arch']}/{r['shape']}", 0.0,
                        {"dominant": r["dominant"],
                         "compute_s": round(r["compute_s"], 4),
                         "collective_s": round(r["collective_s"], 4),
                         "useful_ratio": (round(r["useful_ratio"], 3)
                                          if r["useful_ratio"] else None)})
    return md


if __name__ == "__main__":
    print(run())
