"""Time-evolving scenario suite: every scheme × every scenario (ISSUE 2).

The RQ4/Fig. 17 analogue: each scenario from
:func:`repro.scenarios.default_scenarios` (hot-key flip, straggler
onset/recovery on a heterogeneous pool, scale-out, failure with elastic
continue, churn storm) is run for all six grouping schemes through

* the batched DSPE simulator — each scenario lowered onto a single-edge
  :class:`~repro.topology.Topology` and executed by the unified
  :class:`~repro.topology.SimulatorEngine` (ISSUE 3) — reporting latency /
  throughput / memory overhead / imbalance + tuples remapped per
  membership event, and
* the continuous-batching ServingEngine with the runtime control plane
  (heartbeat failure detection, restart policy, elastic pool remap
  accounting, straggler mitigation) in the loop.

The open-loop suite (ISSUE 8) rides along under the separate
``open_loop`` output key: :func:`repro.scenarios.default_open_loop_scenarios`
(flash crowd over steady Zipf with a bounded shedding ingress queue;
diurnal rate with a mid-run hot-key flip under deferring admission) is run
for all schemes through the arrival-schedule-driven
:class:`~repro.load.OpenLoopDriver`, reporting the admission identity,
queueing delay and total (queue + service) latency.

Emits ``artifacts/BENCH_scenarios.json``.  Module-level ``N_TUPLES`` /
``N_REQUESTS`` / ``OL_RATE`` / ``OL_HORIZON`` are the CI-scale knobs (see
.github/workflows/ci.yml).
"""

from __future__ import annotations

import json
import os
import time

from repro.scenarios import (default_open_loop_scenarios, default_scenarios,
                             run_dspe_scenario, run_open_loop_scenario,
                             run_serving_scenario)
from repro.state import WindowOp

from .common import ARTIFACT_DIR, Reporter, SCHEMES

N_TUPLES = 24_000
N_KEYS = 2_400
WORKERS = 8
N_REQUESTS = 160
ONLY = ()  # scenario-name filter; empty = the full default suite
OL_RATE = 2_000.0   # open-loop mean offered rate (tuples/s)
OL_HORIZON = 4.0    # open-loop arrival horizon (s)


def run(rep: Reporter) -> dict:
    out = {"n_tuples": N_TUPLES, "n_keys": N_KEYS, "workers": WORKERS,
           "n_requests": N_REQUESTS, "scenarios": {}}
    suite = default_scenarios(N_TUPLES, N_KEYS, WORKERS)
    if ONLY:
        suite = [sc for sc in suite if sc.name in ONLY]
    for sc in suite:
        row = {"dspe": {}, "serving": {}}
        # churn scenarios carry a windowed keyed aggregation (ISSUE 4):
        # their rows gain state-migration cost + post-merge exactness.
        # One stream-spanning window keeps every churn point mid-window
        # (a boundary-aligned event rightly migrates nothing)
        dspe_window = (WindowOp(agg="count", size=N_TUPLES)
                       if sc.churn else None)
        srv_window = (WindowOp(agg="count", size=N_REQUESTS)
                      if sc.churn else None)
        for scheme in SCHEMES:
            t0 = time.time()
            r = run_dspe_scenario(sc, scheme, window=dspe_window)
            us = (time.time() - t0) * 1e6
            row["dspe"][scheme] = r
            st = r.get("state")
            rep.add(f"scenario/{sc.name}/dspe/{scheme}", us,
                    f"p99={r['latency_p99']:.4f} "
                    f"remap={r['remap_frac_mean']}"
                    + (f" mig={st['migration_bytes']}B "
                       f"exact={st['exact']}" if st else ""))
        for scheme in SCHEMES:
            t0 = time.time()
            r = run_serving_scenario(sc, scheme, num_requests=N_REQUESTS,
                                     window=srv_window)
            us = (time.time() - t0) * 1e6
            row["serving"][scheme] = r
            rep.add(f"scenario/{sc.name}/serving/{scheme}", us,
                    f"done={r['completed']}/{r['submitted']} "
                    f"p99={r['latency_p99']:.1f}")
        out["scenarios"][sc.name] = row
    out["open_loop"] = {"rate": OL_RATE, "horizon": OL_HORIZON,
                        "scenarios": {}}
    for ol in default_open_loop_scenarios(rate=OL_RATE, horizon=OL_HORIZON,
                                          workers=WORKERS // 2):
        row = {}
        for scheme in SCHEMES:
            t0 = time.time()
            r = run_open_loop_scenario(ol, scheme, engine="batched",
                                       drain=True)
            us = (time.time() - t0) * 1e6
            if not r["identity_ok"]:
                raise AssertionError(
                    f"admission identity broken: {ol.name}/{scheme}: {r}")
            row[scheme] = r
            p99 = r["total_latency_p99"]
            rep.add(f"scenario/open_loop/{ol.name}/{scheme}", us,
                    f"offered={r['offered']} shed={r['shed']} "
                    f"total_p99={p99:.4f}" if p99 is not None else
                    f"offered={r['offered']} shed={r['shed']}")
        out["open_loop"]["scenarios"][ol.name] = row
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, "BENCH_scenarios.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    rep.add("scenario/artifact", 0.0, path)
    return out
