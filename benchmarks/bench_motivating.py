"""Paper Figs. 2-3 (motivating study): latency + memory of existing schemes
on the Amazon-Movie proxy across worker scales."""

from __future__ import annotations

import time

from .common import Reporter, SCHEMES, WORKERS, am_proxy_keys, run_scheme


def run(rep: Reporter) -> dict:
    keys = am_proxy_keys()
    results = {}
    for w in WORKERS:
        for scheme in SCHEMES:
            t0 = time.time()
            _, m = run_scheme(scheme, keys, w)
            us = (time.time() - t0) * 1e6
            results[(scheme, w)] = m
            rep.add(f"fig2_latency_p99/{scheme}/w{w}", us,
                    round(m.latency_p99 * 1e3, 3))
            rep.add(f"fig3_memory_norm/{scheme}/w{w}", us,
                    round(m.memory_overhead_norm, 3))
    # paper's qualitative claims at 128 workers
    fish, sg = results[("fish", 128)], results[("sg", 128)]
    fg = results[("fg", 128)]
    summary = {
        "fish_vs_sg_exec": fish.execution_time / sg.execution_time,
        "fish_mem_norm": fish.memory_overhead_norm,
        "sg_mem_norm": sg.memory_overhead_norm,
        "fg_p99_over_fish": fg.latency_p99 / max(fish.latency_p99, 1e-9),
    }
    rep.add("fig2_3/summary", 0.0, summary)
    return summary
