"""Paper Figs. 12-13 (RQ2): decaying factor α sweep + hot-key threshold θ
sweep — execution time and memory as a function of skew."""

from __future__ import annotations

import time

import numpy as np

from repro.core import simulate_edge
from repro.topology import FishConfig

from .common import Reporter, zf_keys


def _run_fish(keys, w, alpha=0.2, theta_frac=0.25):
    caps = np.full(w, 0.9 * w / 20_000.0)
    g = FishConfig(alpha=alpha, theta_frac=theta_frac).build(w)
    return g, simulate_edge(g, keys, capacities=caps,
                            arrival_rate=20_000.0).metrics


def run(rep: Reporter) -> dict:
    out = {}
    # Fig. 12: alpha in {0, 0.2, 0.5, 0.8, 1.0} ; alpha=1 ignores recency
    for z in (1.0, 1.6):
        keys = zf_keys(z)
        for alpha in (0.0, 0.2, 0.5, 0.8, 1.0):
            for w in (32, 128):
                t0 = time.time()
                g, m = _run_fish(keys, w, alpha=alpha)
                us = (time.time() - t0) * 1e6
                out[("alpha", z, alpha, w)] = (m.execution_time,
                                               m.memory_overhead_norm)
                rep.add(f"fig12_alpha/z{z}/a{alpha}/w{w}", us,
                        {"exec": round(m.execution_time, 4),
                         "mem": round(m.memory_overhead_norm, 3)})
    # Fig. 13: theta in {2/n, 1/n, 1/4n, 1/8n} (theta_frac = theta * n)
    for z in (1.0, 1.6):
        keys = zf_keys(z)
        for tf in (2.0, 1.0, 0.25, 0.125):
            for w in (32, 128):
                t0 = time.time()
                g, m = _run_fish(keys, w, theta_frac=tf)
                us = (time.time() - t0) * 1e6
                out[("theta", z, tf, w)] = (m.execution_time,
                                            m.memory_overhead_norm)
                rep.add(f"fig13_theta/z{z}/tf{tf}/w{w}", us,
                        {"exec": round(m.execution_time, 4),
                         "mem": round(m.memory_overhead_norm, 3)})

    # paper's conclusions: alpha=0.2 best-or-tied; theta=2/n visibly worse
    def exec_at(alpha, z=1.6, w=128):
        return out[("alpha", z, alpha, w)][0]

    summary = {
        "alpha0.2_vs_alpha1_exec": exec_at(0.2) / exec_at(1.0),
        "theta2n_vs_quarter_exec": (out[("theta", 1.6, 2.0, 128)][0]
                                    / out[("theta", 1.6, 0.25, 128)][0]),
    }
    rep.add("fig12_13/summary", 0.0,
            {k: round(v, 3) for k, v in summary.items()})
    return summary
