"""Paper Figs. 18-20 (RQ5, "practical deployment"): the serving-engine
deployment analog — end-to-end latency percentiles, throughput, and relative
memory for all six schemes under a time-evolving session workload."""

from __future__ import annotations

import time

import numpy as np

from repro.serving.engine import Request, ServingEngine

from .common import Reporter

_SCHEMES = ("fg", "pkg", "dc", "wc", "sg", "fish")


def _requests(n: int, seed: int):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        if rng.random() < 0.75:
            # hot session set flips halfway (time-evolving)
            base = 0 if i < n // 2 else 1000
            sess = f"h{base + rng.integers(0, 4)}"
        else:
            sess = f"c{rng.integers(0, 200)}"
        reqs.append((i, sess, float(i) * 0.08, int(rng.integers(4, 12))))
    return reqs


def run(rep: Reporter) -> dict:
    n = 400
    reqs = _requests(n, seed=0)
    speeds = np.concatenate([np.full(4, 2.0), np.full(4, 1.0)])  # hetero
    out = {}
    for scheme in _SCHEMES:
        t0 = time.time()
        eng = ServingEngine(num_replicas=8, slots_per_replica=4,
                            tokens_per_tick=speeds, grouping=scheme)
        for i, sess, arr, tgt in reqs:
            eng.submit(Request(i, sess, arr, tgt))
        eng.run(until_done=n)
        us = (time.time() - t0) * 1e6
        m = eng.metrics()
        out[scheme] = m
        rep.add(f"fig18_latency/{scheme}", us,
                {"avg": round(m.latency_avg, 2), "p50": m.latency_p50,
                 "p99": m.latency_p99})
        rep.add(f"fig19_throughput/{scheme}", us,
                round(m.throughput_tokens, 3))
        rep.add(f"fig20_memory/{scheme}", us,
                round(m.session_replicas_norm, 3))
    summary = {
        "fish_vs_wc_avg_latency_reduction":
            1.0 - out["fish"].latency_avg / max(out["wc"].latency_avg, 1e-9),
        "fish_vs_wc_p99_reduction":
            1.0 - out["fish"].latency_p99 / max(out["wc"].latency_p99, 1e-9),
        "fish_mem_vs_sg":
            out["fish"].session_replicas_norm
            / max(out["sg"].session_replicas_norm, 1e-9),
        "fish_tput_vs_wc":
            out["fish"].throughput_tokens
            / max(out["wc"].throughput_tokens, 1e-9),
    }
    rep.add("fig18_20/summary", 0.0,
            {k: round(v, 3) for k, v in summary.items()})
    return summary
