"""Multi-stage dataflow topologies × all six schemes (ISSUE 3 tentpole).

Two DAG shapes, both fed by a skewed time-evolving source so a hot source
key fans into hot downstream partitions (the multi-hop skew scenario the
topology API opens up):

* ``word_count``     — the classic 2-stage split→count pipeline: shuffle to
  the splitters, the scheme under test on the counting edge (each sentence
  key deterministically fans into ``FANOUT`` word keys, so a hot sentence
  makes hot words).
* ``split_count_agg`` — 3 stages: split→count→aggregate, the scheme under
  test on both keyed edges; the aggregate stage rekeys onto a small vocab
  (many hot words collapse onto one aggregation partition).

Every scheme runs through the batched :class:`SimulatorEngine`; the
2-stage topology additionally runs through the
:class:`ServingTopologyEngine` (continuous-batching replica pools) — the
same ``Topology`` object through both engines.  Emits
``artifacts/BENCH_topology.json`` with per-edge latency percentiles,
imbalance and memory overhead.  Module-level constants are the CI-scale
knobs (see .github/workflows/ci.yml).
"""

from __future__ import annotations

import json
import os
import time

from repro.data.synthetic import zipf_time_evolving
from repro.topology import (Edge, ServingTopologyEngine, ShuffleConfig,
                            SimulatorEngine, Source, Stage, Topology,
                            config_for, hashed_fanout, project_mod)

from .common import ARTIFACT_DIR, Reporter, SCHEMES

N_TUPLES = 20_000
N_KEYS = 2_000
Z = 1.5
ARRIVAL_RATE = 20_000.0
SPLIT_WORKERS = 8
COUNT_WORKERS = 16
AGG_WORKERS = 8
FANOUT = 4
WORD_VOCAB = 1_000
AGG_VOCAB = 64
SERVING_REQUESTS = 192


def word_count_topology(spec) -> Topology:
    """split→count with ``spec`` grouping the counting edge."""
    return Topology(
        name="word_count",
        stages=(
            Stage("split", parallelism=SPLIT_WORKERS,
                  transform=hashed_fanout(FANOUT, WORD_VOCAB)),
            Stage("count", parallelism=COUNT_WORKERS),
        ),
        edges=(
            Edge("source", "split", ShuffleConfig()),
            Edge("split", "count", spec),
        ),
    )


def split_count_agg_topology(spec) -> Topology:
    """split→count→aggregate with ``spec`` on both keyed edges."""
    return Topology(
        name="split_count_agg",
        stages=(
            Stage("split", parallelism=SPLIT_WORKERS,
                  transform=hashed_fanout(FANOUT, WORD_VOCAB)),
            Stage("count", parallelism=COUNT_WORKERS,
                  transform=project_mod(AGG_VOCAB)),
            Stage("agg", parallelism=AGG_WORKERS),
        ),
        edges=(
            Edge("source", "split", ShuffleConfig()),
            Edge("split", "count", spec),
            Edge("count", "agg", spec),
        ),
    )


def _brief(report) -> str:
    er = report.edge("count")
    return (f"count p99={er.latency_p99:.4g} mem={er.memory_overhead} "
            f"imb={er.imbalance:.3f} e2e p99={report.e2e_latency_p99:.4g}")


def run(rep: Reporter) -> dict:
    keys = zipf_time_evolving(N_TUPLES, num_keys=N_KEYS, z=Z, seed=0)
    src = Source(keys, arrival_rate=ARRIVAL_RATE)
    sim = SimulatorEngine()
    serving = ServingTopologyEngine(max_requests=SERVING_REQUESTS)
    out = {
        "n_tuples": N_TUPLES, "n_keys": N_KEYS, "z": Z, "fanout": FANOUT,
        "word_vocab": WORD_VOCAB, "agg_vocab": AGG_VOCAB,
        "serving_requests": SERVING_REQUESTS,
        "two_stage": {}, "three_stage": {}, "two_stage_serving": {},
    }
    for scheme in SCHEMES:
        spec = config_for(scheme)

        t0 = time.time()
        r2 = sim.run(word_count_topology(spec), src)
        rep.add(f"topology/word_count/dspe/{scheme}",
                (time.time() - t0) * 1e6, _brief(r2))
        out["two_stage"][scheme] = r2.to_dict()

        t0 = time.time()
        r3 = sim.run(split_count_agg_topology(spec), src)
        rep.add(f"topology/split_count_agg/dspe/{scheme}",
                (time.time() - t0) * 1e6, _brief(r3))
        out["three_stage"][scheme] = r3.to_dict()

        t0 = time.time()
        rs = serving.run(word_count_topology(spec), src)
        dropped = sum(e.dropped for e in rs.edges)
        rep.add(f"topology/word_count/serving/{scheme}",
                (time.time() - t0) * 1e6,
                _brief(rs) + f" dropped={dropped}")
        out["two_stage_serving"][scheme] = rs.to_dict()

    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, "BENCH_topology.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    rep.add("topology/artifact", 0.0, path)
    return out
