"""Measured operator state, merge cost and migration cost (ISSUE 4).

Reproduces the paper's Fig. 11 memory result from *real* keyed state
instead of the distinct-key counter proxy: every scheme runs a one-window
count aggregation (window = the whole stream, so the stores hold the full
key→count state) through the topology engine with an explicit downstream
merge stage, and the artifact records

* per-worker / total state bytes (open-addressing array stores, logical
  ``ENTRY_BYTES`` per entry) and the FG-normalised total — the Fig. 11
  ordering must emerge from the stores themselves: SG ≫ FG, FISH within
  2× FG even at 128 workers;
* merge cost: partial-aggregate tuples into the merge stage (= state
  entries) and the merge edge's latency;
* post-merge exactness against the routing-free oracle;
* a churn pass (failure + scale-out mid-stream) per scheme: migration
  bytes / tuples replayed under both policies, results still exact.

Emits ``artifacts/BENCH_state.json``.  Module-level constants are the
CI-scale knobs (see .github/workflows/ci.yml).
"""

from __future__ import annotations

import json
import os
import time

from repro.core import MembershipEvent
from repro.data.synthetic import zipf_time_evolving
from repro.state import WindowOp, direct_aggregate
from repro.topology import (Edge, FieldConfig, ScopedEvent, SimulatorEngine,
                            Source, Stage, Topology, config_for)

from .common import ARTIFACT_DIR, Reporter, SCHEMES

N_TUPLES = 30_000
N_KEYS = 3_000
Z = 1.4
ARRIVAL_RATE = 20_000.0
WORKERS = (16, 64, 128)
CHURN_WORKERS = 16
MERGE_WORKERS = 8
BACKEND = "array"


def state_topology(scheme, workers: int, window: WindowOp,
                   merge_workers: int = MERGE_WORKERS) -> Topology:
    """source → windowed count stage (scheme under test) → FG merge."""
    return Topology(
        name=f"state-{scheme}-w{workers}",
        stages=(Stage("count", parallelism=workers, operator=window),
                Stage("merge", parallelism=merge_workers)),
        edges=(Edge("source", "count", config_for(scheme)),
               Edge("count", "merge", FieldConfig())),
    )


def run(rep: Reporter) -> dict:
    keys = zipf_time_evolving(N_TUPLES, num_keys=N_KEYS, z=Z, seed=0)
    n = int(keys.shape[0])
    window = WindowOp(agg="count", size=n, backend=BACKEND)
    oracle = direct_aggregate(keys, window)
    src = Source(keys, arrival_rate=ARRIVAL_RATE)
    sim = SimulatorEngine()
    out = {"n_tuples": n, "n_keys": N_KEYS, "z": Z, "backend": BACKEND,
           "state": {}, "churn": {}}

    # -- Fig. 11 from real state: per-worker stores across worker counts -----
    fg_bytes = {}
    for w in WORKERS:
        for scheme in SCHEMES:
            t0 = time.time()
            r = sim.run(state_topology(scheme, w, window), src)
            us = (time.time() - t0) * 1e6
            st = r.state["count"]
            er = r.edge("count")
            mrg = r.edge("merge")
            exact = st["merged"] == oracle
            row = {
                "workers": w,
                "state_bytes": st["state_bytes_final"],
                "state_bytes_peak": st["state_bytes_peak"],
                "per_worker_max": max(st["per_worker_bytes"]),
                "merge_tuples": mrg.n_tuples,
                "merge_latency_p99": mrg.latency_p99,
                "exact": exact,
            }
            if scheme == "fg":
                fg_bytes[w] = st["state_bytes_final"]
            row["norm_vs_fg"] = (st["state_bytes_final"]
                                 / max(fg_bytes.get(w, 0), 1))
            out["state"][f"{scheme}/w{w}"] = row
            rep.add(f"state_bytes/{scheme}/w{w}", us,
                    f"bytes={row['state_bytes']} norm={row['norm_vs_fg']:.2f} "
                    f"merge={row['merge_tuples']} exact={exact}")
            assert exact, (scheme, w)

    # Fig. 11 ordering acceptance: SG ≫ FG; FISH within 2× FG at 128
    w_hi = WORKERS[-1]
    sg_norm = out["state"][f"sg/w{w_hi}"]["norm_vs_fg"]
    fish_norm = out["state"][f"fish/w{w_hi}"]["norm_vs_fg"]
    assert out["state"][f"fg/w{w_hi}"]["norm_vs_fg"] == 1.0
    assert sg_norm > 3.0, f"SG must replicate state heavily, got {sg_norm}"
    assert fish_norm < 2.0, f"FISH must stay near FG state, got {fish_norm}"
    rep.add(f"state_bytes/ordering_at_w{w_hi}", 0.0,
            f"sg={sg_norm:.2f} fish={fish_norm:.2f} fg=1.0")

    # -- churn: failure + scale-out mid-stream, both migration policies ------
    events = [
        ScopedEvent("count", MembershipEvent(
            at=n // 3, workers=tuple(x for x in range(CHURN_WORKERS)
                                     if x != CHURN_WORKERS - 1))),
        ScopedEvent("count", MembershipEvent(
            at=2 * n // 3, workers=tuple(x for x in range(CHURN_WORKERS + 1)
                                         if x != CHURN_WORKERS - 1))),
    ]
    for policy in ("migrate", "rebuild"):
        wop = WindowOp(agg="count", size=n, backend=BACKEND,
                       migration=policy)
        for scheme in SCHEMES:
            t0 = time.time()
            r = sim.run(state_topology(scheme, CHURN_WORKERS, wop), src,
                        events)
            us = (time.time() - t0) * 1e6
            st = r.state["count"]
            exact = st["merged"] == oracle
            row = {
                "policy": policy,
                "migration_bytes": st["migration_bytes"],
                "migration_events": st["migration_events"],
                "tuples_replayed": st["tuples_replayed"],
                "exact": exact,
            }
            out["churn"][f"{scheme}/{policy}"] = row
            rep.add(f"state_churn/{scheme}/{policy}", us,
                    f"mig={row['migration_bytes']}B "
                    f"replay={row['tuples_replayed']} exact={exact}")
            assert exact, (scheme, policy)
            if policy == "migrate":
                assert row["migration_bytes"] > 0, scheme
            else:
                assert row["tuples_replayed"] > 0, scheme

    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, "BENCH_state.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    rep.add("state/artifact", 0.0, path)
    return out
