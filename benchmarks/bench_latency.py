"""Paper Figs. 9-10: execution time (normalised to SG) on the real-dataset
proxies (AM, MT) and the synthetic ZF dataset across skews.

Runs through the unified topology engine protocol (ISSUE 3): every scheme
is a single-edge :class:`~repro.topology.Topology` on
:class:`~repro.topology.SimulatorEngine` via :func:`common.run_edge`.
"""

from __future__ import annotations

import time

from .common import Reporter, WORKERS, am_proxy_keys, mt_proxy_keys, \
    run_edge, zf_keys

_SCHEMES = ("pkg", "dc", "wc", "fish")


def run(rep: Reporter) -> dict:
    out = {}
    for ds_name, keys in (("am", am_proxy_keys()), ("mt", mt_proxy_keys())):
        for w in WORKERS:
            m_sg = run_edge("sg", keys, w)
            for scheme in _SCHEMES:
                t0 = time.time()
                m = run_edge(scheme, keys, w)
                us = (time.time() - t0) * 1e6
                norm = m.execution_time / m_sg.execution_time
                out[(ds_name, scheme, w)] = norm
                rep.add(f"fig9_exec_vs_sg/{ds_name}/{scheme}/w{w}", us,
                        round(norm, 3))
    for z in (1.0, 1.4, 1.8):
        keys = zf_keys(z)
        for w in (16, 128):
            m_sg = run_edge("sg", keys, w)
            for scheme in _SCHEMES:
                t0 = time.time()
                m = run_edge(scheme, keys, w)
                us = (time.time() - t0) * 1e6
                norm = m.execution_time / m_sg.execution_time
                out[("zf", z, scheme, w)] = norm
                rep.add(f"fig10_exec_vs_sg/zf{z}/{scheme}/w{w}", us,
                        round(norm, 3))
    fish_worst = max(v for k, v in out.items() if "fish" in k)
    rep.add("fig9_10/fish_worst_vs_sg", 0.0, round(fish_worst, 3))
    return {"fish_worst_vs_sg": fish_worst}
