"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus a summary footer).

    PYTHONPATH=src python -m benchmarks.run [--only fig9] [--trace DIR]
    PYTHONPATH=src python -m benchmarks.run --sanitize

``--trace DIR`` records one Perfetto-loadable Chrome trace-event file per
benchmark module (``DIR/<module>.trace.json``) by enabling process-wide
telemetry around each ``run()``.  A module that fails still leaves a
*valid* sealed trace (stamped ``aborted``) — never truncated JSON.

``--sanitize`` skips the benchmarks and runs the ISSUE-10 differential
sanitizer instead (:mod:`repro.analysis.sanitize`): one fused-engine and
one serving-engine session, each run twice with the same seed under
``np.seterr(all="raise")`` + ``jax_debug_nans``, the two
``TopologyReport``\\ s diffed field-by-field bit-for-bit.  Exit 1 on any
divergence or numeric fault — the dynamic gate CI pairs with the static
``repro.analysis`` scan.
"""

from __future__ import annotations

import argparse
import os
import sys
import traceback

from .common import Reporter


def _sanitize_targets():
    """(name, factory) pairs for the sanitizer: each factory builds a fresh
    engine + topology + source and returns a TopologyReport.  One fused
    simulator session and one serving session — the two engines whose
    device/tick paths the static rules cannot fully see."""
    import numpy as np

    from repro.data.synthetic import zipf_time_evolving
    from repro.topology import (Edge, ServingTopologyEngine, SimulatorEngine,
                                Source, Stage, Topology, config_for)

    def topo(name):
        return Topology(name=name,
                        stages=(Stage("worker", parallelism=32),),
                        edges=(Edge("source", "worker", config_for("pkg")),))

    def keys():
        return np.asarray(zipf_time_evolving(
            20_000, num_keys=2_000, z=1.2, flip_head=600, seed=7))

    def fused():
        return SimulatorEngine(mode="fused", seed=3).run(
            topo("sanitize-fused"), Source(keys(), arrival_rate=20_000.0))

    def serving():
        return ServingTopologyEngine(max_requests=64).run(
            topo("sanitize-serving"), Source(keys(), arrival_rate=20_000.0))

    return [("fused", fused), ("serving", serving)]


def _sanitize() -> int:
    from repro.analysis.sanitize import double_run

    failed = 0
    for name, factory in _sanitize_targets():
        try:
            _, _, divergences = double_run(factory)
        except Exception as e:
            if not isinstance(e, FloatingPointError):
                traceback.print_exc()
            print(f"sanitize[{name}]: FAIL — "
                  f"{type(e).__name__} under strict numerics: {e}")
            failed += 1
            continue
        if divergences:
            print(f"sanitize[{name}]: FAIL — same-seed runs diverge:")
            for d in divergences:
                print(f"  {d}")
            failed += 1
        else:
            print(f"sanitize[{name}]: PASS — double run bit-identical")
    return 1 if failed else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark module name")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="record a Chrome trace per module into DIR")
    ap.add_argument("--sanitize", action="store_true",
                    help="run the differential sanitizer (same-seed double "
                    "run under strict numerics) instead of the benchmarks")
    args = ap.parse_args()

    if args.sanitize:
        sys.exit(_sanitize())

    from . import (bench_breakdown, bench_chash, bench_deploy,
                   bench_feed_fused, bench_grouping, bench_latency,
                   bench_memory, bench_moe, bench_motivating, bench_params,
                   bench_scenarios, bench_session, bench_slo, bench_state,
                   bench_topology, roofline)

    modules = [
        ("bench_motivating", bench_motivating),   # Figs. 2-3
        ("bench_grouping", bench_grouping),       # batched engine tps (ISSUE 1)
        ("bench_latency", bench_latency),         # Figs. 9-10
        ("bench_memory", bench_memory),           # Fig. 11
        ("bench_params", bench_params),           # Figs. 12-13
        ("bench_breakdown", bench_breakdown),     # Figs. 14-16
        ("bench_chash", bench_chash),             # Fig. 17
        ("bench_scenarios", bench_scenarios),     # RQ4 scenario suite (ISSUE 2)
        ("bench_topology", bench_topology),       # multi-stage DAGs (ISSUE 3)
        ("bench_state", bench_state),             # keyed operator state (ISSUE 4)
        ("bench_session", bench_session),         # streaming sessions (ISSUE 5)
        ("bench_feed_fused", bench_feed_fused),   # fused device feeds (ISSUE 6)
        ("bench_slo", bench_slo),                 # open-loop SLO sweep (ISSUE 8)
        ("bench_deploy", bench_deploy),           # Figs. 18-20
        ("bench_moe", bench_moe),                 # beyond-paper MoE routing
        ("roofline", roofline),                   # §Roofline table
    ]

    rep = Reporter()
    for name, mod in modules:
        if args.only and args.only not in name:
            continue
        writer = None
        if args.trace:
            from repro.obs import telemetry
            from repro.obs.export import TraceWriter

            telemetry.enable(label=name)
            writer = TraceWriter(
                os.path.join(args.trace, f"{name}.trace.json"))
            rep.attach_trace(writer)
        try:
            mod.run(rep)
            if writer is not None:
                tel = telemetry.get_telemetry()
                writer.write_telemetry(tel)
                writer.close({"label": name,
                              "metrics": tel.metrics.snapshot(),
                              "timeline": tel.timeline.export()})
        except Exception as e:
            traceback.print_exc()
            # recorded apart from the measurements: the CSV must carry only
            # real numbers, never a zero-valued ERROR row — and the partial
            # trace (if recording) is sealed by add_failure, not truncated
            rep.add_failure(name, e)
        finally:
            if args.trace:
                telemetry.disable()
                rep.attach_trace(None)
    print(rep.csv())
    if rep.failures:
        print(rep.failure_summary(), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
