"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus a summary footer).

    PYTHONPATH=src python -m benchmarks.run [--only fig9] [--trace DIR]

``--trace DIR`` records one Perfetto-loadable Chrome trace-event file per
benchmark module (``DIR/<module>.trace.json``) by enabling process-wide
telemetry around each ``run()``.  A module that fails still leaves a
*valid* sealed trace (stamped ``aborted``) — never truncated JSON.
"""

from __future__ import annotations

import argparse
import os
import sys
import traceback

from .common import Reporter


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark module name")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="record a Chrome trace per module into DIR")
    args = ap.parse_args()

    from . import (bench_breakdown, bench_chash, bench_deploy,
                   bench_feed_fused, bench_grouping, bench_latency,
                   bench_memory, bench_moe, bench_motivating, bench_params,
                   bench_scenarios, bench_session, bench_slo, bench_state,
                   bench_topology, roofline)

    modules = [
        ("bench_motivating", bench_motivating),   # Figs. 2-3
        ("bench_grouping", bench_grouping),       # batched engine tps (ISSUE 1)
        ("bench_latency", bench_latency),         # Figs. 9-10
        ("bench_memory", bench_memory),           # Fig. 11
        ("bench_params", bench_params),           # Figs. 12-13
        ("bench_breakdown", bench_breakdown),     # Figs. 14-16
        ("bench_chash", bench_chash),             # Fig. 17
        ("bench_scenarios", bench_scenarios),     # RQ4 scenario suite (ISSUE 2)
        ("bench_topology", bench_topology),       # multi-stage DAGs (ISSUE 3)
        ("bench_state", bench_state),             # keyed operator state (ISSUE 4)
        ("bench_session", bench_session),         # streaming sessions (ISSUE 5)
        ("bench_feed_fused", bench_feed_fused),   # fused device feeds (ISSUE 6)
        ("bench_slo", bench_slo),                 # open-loop SLO sweep (ISSUE 8)
        ("bench_deploy", bench_deploy),           # Figs. 18-20
        ("bench_moe", bench_moe),                 # beyond-paper MoE routing
        ("roofline", roofline),                   # §Roofline table
    ]

    rep = Reporter()
    for name, mod in modules:
        if args.only and args.only not in name:
            continue
        writer = None
        if args.trace:
            from repro.obs import telemetry
            from repro.obs.export import TraceWriter

            telemetry.enable(label=name)
            writer = TraceWriter(
                os.path.join(args.trace, f"{name}.trace.json"))
            rep.attach_trace(writer)
        try:
            mod.run(rep)
            if writer is not None:
                tel = telemetry.get_telemetry()
                writer.write_telemetry(tel)
                writer.close({"label": name,
                              "metrics": tel.metrics.snapshot(),
                              "timeline": tel.timeline.export()})
        except Exception as e:
            traceback.print_exc()
            # recorded apart from the measurements: the CSV must carry only
            # real numbers, never a zero-valued ERROR row — and the partial
            # trace (if recording) is sealed by add_failure, not truncated
            rep.add_failure(name, e)
        finally:
            if args.trace:
                telemetry.disable()
                rep.attach_trace(None)
    print(rep.csv())
    if rep.failures:
        print(rep.failure_summary(), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
