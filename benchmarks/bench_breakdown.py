"""Paper Figs. 14-16 (RQ3 breakdown): epoch identification, CHK, heuristic
worker assignment — each ablated independently."""

from __future__ import annotations

import time

import numpy as np

from repro.core import simulate_edge
from repro.topology import FishConfig

from .common import Reporter, run_scheme, zf_keys


def _fish(keys, w, caps=None, **pkw):
    if caps is None:
        caps = np.full(w, 0.9 * w / 20_000.0)
    # grouper discovers capacities via sampling — no oracle seeding
    g = FishConfig(**pkw).build(w)
    return g, simulate_edge(g, keys, capacities=caps,
                            arrival_rate=20_000.0).metrics


def run(rep: Reporter) -> dict:
    out = {}
    # Fig. 14 — epoch-based identification: w/ epoch (alpha=0.2, epoch=1000)
    # vs w/o epoch (alpha=1.0, epoch=inf: lifetime counting as in D-C/W-C)
    for z in (1.2, 1.6):
        keys = zf_keys(z)
        for w in (32, 128):
            t0 = time.time()
            _, m_with = _fish(keys, w, alpha=0.2, epoch=1000)
            _, m_without = _fish(keys, w, alpha=1.0, epoch=2**62)
            us = (time.time() - t0) * 1e6
            ratio = m_without.execution_time / m_with.execution_time
            out[("epoch", z, w)] = ratio
            rep.add(f"fig14_epoch_ablation/z{z}/w{w}", us,
                    {"wo_over_w_exec": round(ratio, 3)})

    # Fig. 15 — CHK vs the W-C / D-C hot-key handling (memory + exec)
    for z in (1.2,):
        keys = zf_keys(z)
        for w in (64, 128):
            t0 = time.time()
            _, m_chk = _fish(keys, w)
            _, m_wc = run_scheme("wc", keys, w)
            _, m_dc = run_scheme("dc", keys, w)
            us = (time.time() - t0) * 1e6
            out[("chk", z, w)] = (m_chk.memory_overhead,
                                  m_wc.memory_overhead, m_dc.memory_overhead)
            rep.add(f"fig15_chk/z{z}/w{w}", us, {
                "chk_mem": m_chk.memory_overhead,
                "wc_mem": m_wc.memory_overhead,
                "dc_mem": m_dc.memory_overhead,
                "chk_exec": round(m_chk.execution_time, 4),
                "dc_exec": round(m_dc.execution_time, 4),
            })

    # Fig. 16 — heuristic worker assignment under heterogeneous capacity:
    # half the workers 2x faster; 'hwa off' = FISH with capacities hidden
    for w in (32, 128):
        keys = zf_keys(1.4)
        caps = np.concatenate([
            np.full(w // 2, 1.0), np.full(w - w // 2, 0.5)
        ]) * 0.9 * w / 20_000.0 / 0.75  # same aggregate service rate
        t0 = time.time()
        g_on, m_on = _fish(keys, w, caps=caps)
        # hwa off: estimator believes all workers are equal and gets no
        # capacity samples (previous studies' count-based assignment)
        g_off = FishConfig().build(w)
        m_off = simulate_edge(g_off, keys, capacities=caps,
                              arrival_rate=20_000.0, sample_every=0).metrics
        us = (time.time() - t0) * 1e6
        ratio = m_off.execution_time / m_on.execution_time
        out[("hwa", w)] = ratio
        rep.add(f"fig16_hwa/w{w}", us, {"off_over_on_exec": round(ratio, 3)})

    return {k: v for k, v in out.items() if k[0] in ("epoch", "hwa")}
