"""Paper Fig. 17 (RQ4): consistent hashing under dynamic worker change —
memory overhead with vs without CH when a worker joins/leaves mid-stream."""

from __future__ import annotations

import time

from repro.core import MembershipEvent, simulate_edge
from repro.topology import FishConfig

from .common import N_TUPLES, Reporter, zf_keys


def run(rep: Reporter) -> dict:
    out = {}
    w = 16
    for z in (1.0, 1.6):
        keys = zf_keys(z)
        for op, new_set in (("add", list(range(w + 1))),
                            ("remove", list(range(w - 1)))):
            ev = [MembershipEvent(at=N_TUPLES // 2, workers=new_set)]
            t0 = time.time()
            g_ch = FishConfig(use_consistent_hash=True).build(w)
            m_ch = simulate_edge(g_ch, keys, arrival_rate=20_000.0,
                                 events=ev).metrics
            g_no = FishConfig(use_consistent_hash=False).build(w)
            m_no = simulate_edge(g_no, keys, arrival_rate=20_000.0,
                                 events=ev).metrics
            us = (time.time() - t0) * 1e6
            ratio = m_no.memory_overhead / max(m_ch.memory_overhead, 1)
            out[(z, op)] = ratio
            rep.add(f"fig17_chash/{op}/z{z}", us,
                    {"no_ch_over_ch_mem": round(ratio, 3),
                     "ch_mem": m_ch.memory_overhead,
                     "no_ch_mem": m_no.memory_overhead})
    return out
