"""Streaming session API: steady-state per-feed latency (ISSUE 5).

Measures what the session redesign is for — incremental record-batch
execution — against the one-shot baseline:

* ``one_shot``: wall-clock of ``Engine.run`` over the whole stream (the
  pre-session execution mode, and the throughput ceiling: one giant batch
  amortises every per-call overhead);
* ``feeds``: the same stream cut into record batches of 256 → 16k tuples
  and pushed through ``open → feed* → close``.  Per batch size the
  artifact records the steady-state per-feed wall-clock (median over the
  feeds after the first — the first feed pays grouper/caps/state setup),
  the implied tuples/s, and the relative throughput vs one-shot — i.e.
  the amortisation curve a caller picks a batch size on.

Equivalence is asserted, not assumed: the session run must route every
tuple (same n, same memory_overhead as ``run``) for the exact schemes.

Emits ``artifacts/BENCH_session.json``.  Module-level constants are the
CI-scale knobs (see .github/workflows/ci.yml).
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.data.synthetic import zipf_time_evolving
from repro.topology import (Edge, SimulatorEngine, Source, Stage, Topology,
                            config_for)

from .common import ARTIFACT_DIR, Reporter

N_TUPLES = 48_000
N_KEYS = 4_000
Z = 1.4
ARRIVAL_RATE = 20_000.0
WORKERS = 32
BATCH_SIZES = (256, 1_024, 4_096, 16_384)
SCHEMES = ("sg", "pkg", "fish")


def _topology(scheme) -> Topology:
    return Topology(
        name=f"session-{scheme}",
        stages=(Stage("worker", parallelism=WORKERS),),
        edges=(Edge("source", "worker", config_for(scheme)),),
    )


def run(rep: Reporter) -> dict:
    keys = zipf_time_evolving(N_TUPLES, num_keys=N_KEYS, z=Z, seed=0)
    n = int(keys.shape[0])
    src = Source(keys, arrival_rate=ARRIVAL_RATE)
    out = {"n_tuples": n, "n_keys": N_KEYS, "workers": WORKERS,
           "one_shot": {}, "feeds": {}}

    for scheme in SCHEMES:
        eng = SimulatorEngine()
        topo = _topology(scheme)
        t0 = time.time()
        base = eng.run(topo, src)
        one_shot_s = time.time() - t0
        out["one_shot"][scheme] = {
            "seconds": one_shot_s,
            "tuples_per_s": n / max(one_shot_s, 1e-12),
        }
        rep.add(f"session/one_shot/{scheme}", one_shot_s * 1e6,
                f"{n / max(one_shot_s, 1e-12):.0f} tup/s")

        out["feeds"][scheme] = {}
        for bs in BATCH_SIZES:
            session = eng.open(topo, arrival_rate=ARRIVAL_RATE)
            per_feed = []
            for batch in src.iter_batches(batch_size=bs):
                t0 = time.time()
                session.feed(batch)
                per_feed.append(time.time() - t0)
            t0 = time.time()
            report = session.close()
            close_s = time.time() - t0
            # steady state: the first feed pays edge setup (grouper build,
            # capacity planning, ring warm-up) — exclude it
            steady = np.asarray(per_feed[1:] or per_feed)
            p50 = float(np.median(steady))
            row = {
                "batch_size": bs,
                "n_feeds": len(per_feed),
                "per_feed_ms_p50": p50 * 1e3,
                "per_feed_ms_p95": float(np.percentile(steady, 95)) * 1e3,
                "first_feed_ms": per_feed[0] * 1e3,
                "close_ms": close_s * 1e3,
                "tuples_per_s": bs / max(p50, 1e-12),
                "rel_throughput_vs_one_shot": (
                    (bs / max(p50, 1e-12))
                    / (n / max(one_shot_s, 1e-12))),
            }
            out["feeds"][scheme][str(bs)] = row
            rep.add(f"session/feed/{scheme}/b{bs}", p50 * 1e6,
                    f"{row['tuples_per_s']:.0f} tup/s "
                    f"({row['rel_throughput_vs_one_shot']:.2f}x one-shot)")
            # the session routed the whole stream through the same edge
            assert report.edge("worker").n_tuples == n, (scheme, bs)
            if scheme in ("sg", "pkg"):  # sequentially exact schemes
                assert (report.edge("worker").memory_overhead
                        == base.edge("worker").memory_overhead), (scheme, bs)

    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, "BENCH_session.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    rep.add("session/artifact", 0.0, path)
    return out
