"""Beyond-paper: FISH expert routing inside the MoE layer (DESIGN.md §1.2).

Measures drop fraction and expert load imbalance for fg / pkg / fish routing
under a *time-evolving* token mixture (the router's hot experts drift), on
the reduced deepseek config."""

from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.models.moe import init_hotness, init_moe_params, moe_ffn

from .common import Reporter


def run(rep: Reporter) -> dict:
    cfg = reduced_config(get_config("deepseek-v2-lite-16b"))
    moe = cfg.moe
    key = jax.random.PRNGKey(0)
    params = init_moe_params(key, cfg.d_model, moe)
    t_tokens, d = 512, cfg.d_model

    # time-evolving mixture: cluster means drift each step
    rng = np.random.default_rng(0)
    means = rng.normal(size=(4, d)).astype(np.float32)

    out = {}
    for mode in ("fg", "pkg", "fish"):
        m2 = dataclasses.replace(moe, routing=mode)
        fn = jax.jit(lambda p, x, h: moe_ffn(p, x, m2, h))
        hot = init_hotness(moe.num_experts)
        drops, imbs = [], []
        t0 = time.time()
        for step in range(12):
            drift = means[(step // 3) % 4]
            x = (rng.normal(size=(t_tokens, d)) * 0.5 + drift).astype(
                np.float32)
            y, hot, aux, metrics = fn(params, jnp.asarray(x, jnp.bfloat16),
                                      hot)
            drops.append(float(metrics["moe_drop_frac"]))
            imbs.append(float(metrics["moe_load_max_over_mean"]))
        us = (time.time() - t0) * 1e6
        out[mode] = {"drop": float(np.mean(drops[3:])),
                     "imb": float(np.mean(imbs[3:]))}
        rep.add(f"moe_routing/{mode}", us,
                {k: round(v, 4) for k, v in out[mode].items()})
    rep.add("moe_routing/fish_vs_fg_drop", 0.0,
            round(out["fish"]["drop"] / max(out["fg"]["drop"], 1e-9), 3))
    return out
