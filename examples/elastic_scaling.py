"""Elastic scaling + fault tolerance walkthrough (paper §5 / Fig. 17).

Streams documents through the FISH pipeline while hosts join and leave;
heartbeat monitoring + the restart policy decide elastic-continue vs
checkpoint-restart; consistent hashing bounds how much key->host state moves.

    PYTHONPATH=src python examples/elastic_scaling.py
"""

import numpy as np

from repro.core.fish import FishParams
from repro.data.pipeline import StreamingPipeline
from repro.data.synthetic import token_stream
from repro.runtime.elastic import ElasticPool
from repro.runtime.fault import HeartbeatMonitor, RestartPolicy


def main() -> None:
    hosts = list(range(8))
    pipe = StreamingPipeline(num_hosts=8, seq_len=32, batch_per_host=1,
                             grouping="fish",
                             fish_params=FishParams(epoch=500, k_max=256))
    pool = ElasticPool(hosts)
    mon = HeartbeatMonitor(hosts, timeout=5.0)
    policy = RestartPolicy(total_hosts=8, max_lost_frac=0.25,
                           on_rescale=lambda alive: pipe.rescale(alive))

    stream = token_stream(3000, num_keys=400, doc_len=16, vocab_size=1000,
                          z=1.3, seed=0)
    sample_keys = [f"doc{i}" for i in range(2000)]

    clock = 0.0
    for i, (key, toks) in enumerate(stream):
        clock += 0.01
        pipe.ingest(key, toks)
        for h in pipe.grouper.ring.workers:
            if not (h == 5 and i > 1000):   # host 5 goes silent after doc 1000
                mon.heartbeat(h, clock)
        if i % 200 == 0:
            dead = mon.check(clock)
            if dead:
                status = policy.handle(mon, clock)
                moved = pool.remove_host(dead[0], sample_keys)
                print(f"[t={clock:6.1f}] host {dead[0]} dead -> {status}; "
                      f"{moved}/{len(sample_keys)} sample keys remapped "
                      f"({moved/len(sample_keys):.1%}, ~1/8 expected)")
        if i == 2200:  # scale out
            new = 8
            moved = pool.add_host(new, sample_keys)
            pipe.rescale(sorted(set(pipe.grouper.ring.workers) | {new}))
            print(f"[t={clock:6.1f}] host {new} joined; {moved} keys moved "
                  f"({moved/len(sample_keys):.1%})")

    routed = pipe._docs_routed
    print(f"\ndocs routed per host: {routed.tolist()}")
    print(f"pipeline memory overhead (key replicas): "
          f"{pipe.memory_overhead()} "
          f"({pipe.grouper.memory_overhead_normalized():.2f}x FG)")


if __name__ == "__main__":
    main()
