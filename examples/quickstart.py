"""Quickstart: FISH vs all baseline groupings through the topology API.

Reproduces the paper's headline in one minute on CPU: FISH gets Shuffle-level
load balance at Field-Grouping-level memory.  Each scheme is a typed config
on the edge of a one-stage :class:`~repro.topology.Topology`, run by the
DSPE :class:`~repro.topology.SimulatorEngine`; the same ``Topology`` object
would run unchanged on the serving engine (``ServingTopologyEngine``).

The second section is the streaming session API (ISSUE 5): the same stream
fed incrementally as record batches — ``engine.open`` → ``session.feed`` →
``session.close`` — with the ZF hot-key flip split across the feed
boundary, exactly the long-running-DSPE situation FISH's epoch machinery
exists for.

The third section is the open-loop load subsystem (ISSUE 8): a flash
crowd arrives on a wall-clock schedule that does not care whether the
engine keeps up, a bounded ingress queue sheds what the backpressured
driver cannot feed, and the accounting closes exactly —
``offered == fed + shed + residual``.

The fourth section is the telemetry spine (ISSUE 9): the same flash
crowd recorded with process-wide telemetry enabled — every layer lands
on one Perfetto-loadable trace, and ``python -m repro.obs summarize``
prints the span/counter/metric overview from the saved file.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

from repro.data.synthetic import zipf_time_evolving
from repro.load import (ArrivalProcess, ConstantRate, FlashCrowd,
                        IngressQueue, OpenLoopDriver, ZipfKeys)
from repro.topology import (Edge, SimulatorEngine, Source, Stage, Topology,
                            config_for)


def one_shot(workers: int, source: Source) -> None:
    engine = SimulatorEngine()
    print(f"{'scheme':8s} {'exec(s)':>9s} {'p99 lat(ms)':>12s} "
          f"{'mem (vs FG)':>12s} {'imbalance':>10s}")
    for scheme in ("sg", "fg", "pkg", "dc", "wc", "fish"):
        topo = Topology(
            name=f"quickstart-{scheme}",
            stages=(Stage("worker", parallelism=workers),),
            edges=(Edge("source", "worker", config_for(scheme)),),
        )
        m = engine.run(topo, source).edge("worker")
        print(f"{scheme:8s} {m.execution_time:9.3f} "
              f"{m.latency_p99 * 1e3:12.2f} {m.memory_overhead_norm:12.2f} "
              f"{m.imbalance:10.3f}")
    print("\nFISH should sit within ~1.3x of SG's execution time while "
          "holding memory within a few x of FG (paper Figs. 9-11).")


def session_api(workers: int, source: Source) -> None:
    """Feed the ZF stream as two record batches split at the 0.8*N hot-key
    flip: FISH's epoch state carries across the feed boundary, so the
    post-flip batch is routed by a grouper that already learned the
    pre-flip hot set — and must now unlearn it online."""
    engine = SimulatorEngine()
    topo = Topology(
        name="quickstart-session",
        stages=(Stage("worker", parallelism=workers),),
        edges=(Edge("source", "worker", config_for("fish")),),
    )
    session = engine.open(topo, arrival_rate=source.arrival_rate)
    n = int(source.keys.shape[0])
    flip = int(0.8 * n)  # the ZF generator flips the hot head here
    batches = list(source.iter_batches(batch_size=flip))
    for i, batch in enumerate(batches):
        session.feed(batch)
        print(f"feed {i}: {len(batch):6d} tuples "
              f"({'pre' if i == 0 else 'post'}-flip)")
    m = session.close().edge("worker")
    print(f"fish via 2-batch session: exec {m.execution_time:.3f}s, "
          f"p99 {m.latency_p99 * 1e3:.2f}ms, imbalance {m.imbalance:.3f}")
    print("(feeding everything as one batch is bit-identical to "
          "engine.run)")


def open_loop(workers: int) -> None:
    """Overload is only observable open loop: offer a 3x flash crowd to a
    pool provisioned for 0.8 utilization at the base rate, through a
    bounded shedding ingress queue with driver backpressure."""
    rate = 2_000.0
    topo = Topology(
        name="quickstart-open-loop",
        stages=(Stage("worker", parallelism=workers,
                      cost=0.8 * workers / rate),),
        edges=(Edge("source", "worker", config_for("fish")),),
    )
    session = SimulatorEngine().open(topo, arrival_rate=rate)
    arrivals = ArrivalProcess(
        ConstantRate(rate) * FlashCrowd(at=1.5, duration=1.0, magnitude=3.0),
        ZipfKeys(1_024, z=1.2), tick=0.05, seed=0)
    driver = OpenLoopDriver(session, IngressQueue(400, policy="shed"),
                            backpressure=0.25)
    rep = driver.run(arrivals, 0.0, 4.0, drain=True)
    assert rep.offered == rep.fed + rep.shed_ingress + rep.residual
    print(f"offered {rep.offered}, fed {rep.fed}, shed {rep.shed} "
          f"(queue depth peak {rep.queue_depth_peak})")
    print(f"queue-delay p99 {rep.queue_delay_p99 * 1e3:.1f}ms + service -> "
          f"total p99 {rep.total_latency_p99 * 1e3:.1f}ms")
    print("(the flash crowd shows up as queueing delay and honest shed, "
          "never as a silently stretched input schedule)")


def telemetry_trace(workers: int) -> None:
    """Record the flash-crowd run with telemetry on: the driver, session,
    FISH epoch observer and admission control all land on one engine-clock-
    stamped trace.  The saved file loads in Perfetto (ui.perfetto.dev)."""
    from repro.obs import telemetry

    rate = 2_000.0
    topo = Topology(
        name="quickstart-trace",
        stages=(Stage("worker", parallelism=workers,
                      cost=0.8 * workers / rate),),
        edges=(Edge("source", "worker", config_for("fish")),),
    )
    tel = telemetry.enable(label="quickstart flash crowd")
    try:
        session = SimulatorEngine().open(topo, arrival_rate=rate)
        arrivals = ArrivalProcess(
            ConstantRate(rate) * FlashCrowd(at=1.5, duration=1.0,
                                            magnitude=3.0),
            ZipfKeys(1_024, z=1.2), tick=0.05, seed=0)
        driver = OpenLoopDriver(session, IngressQueue(400, policy="shed"),
                                backpressure=0.25)
        rep = driver.run(arrivals, 0.0, 4.0, drain=True)
    finally:
        telemetry.disable()
    path = os.path.join(tempfile.gettempdir(), "quickstart.trace.json")
    tel.save(path)
    series = rep.to_dict()["timeline"]["series"]
    print(f"trace saved to {path} — load it at ui.perfetto.dev")
    print(f"report timeline series: {', '.join(sorted(series))}")
    print("summary (python -m repro.obs summarize):")
    from repro.obs.cli import main as obs_summarize
    obs_summarize(["summarize", path])


def main() -> None:
    workers = 32
    keys = zipf_time_evolving(40_000, num_keys=4_000, z=1.4, seed=0)
    source = Source(keys, arrival_rate=20_000.0)
    one_shot(workers, source)
    print()
    session_api(workers, source)
    print()
    open_loop(workers=8)
    print()
    telemetry_trace(workers=8)


if __name__ == "__main__":
    main()
