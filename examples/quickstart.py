"""Quickstart: FISH vs all baseline groupings on the paper's ZF dataset.

Reproduces the paper's headline in one minute on CPU: FISH gets Shuffle-level
load balance at Field-Grouping-level memory.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import make_grouper, simulate_stream
from repro.data.synthetic import zipf_time_evolving


def main() -> None:
    workers = 32
    keys = zipf_time_evolving(40_000, num_keys=4_000, z=1.4, seed=0)
    caps = np.full(workers, 0.9 * workers / 20_000.0)

    print(f"{'scheme':8s} {'exec(s)':>9s} {'p99 lat(ms)':>12s} "
          f"{'mem (vs FG)':>12s} {'imbalance':>10s}")
    base_exec = None
    for scheme in ("sg", "fg", "pkg", "dc", "wc", "fish"):
        g = make_grouper(scheme, workers)
        m = simulate_stream(g, keys, capacities=caps, arrival_rate=20_000.0)
        if scheme == "sg":
            base_exec = m.execution_time
        print(f"{scheme:8s} {m.execution_time:9.3f} "
              f"{m.latency_p99 * 1e3:12.2f} {m.memory_overhead_norm:12.2f} "
              f"{m.imbalance:10.3f}")
    print("\nFISH should sit within ~1.3x of SG's execution time while "
          "holding memory within a few x of FG (paper Figs. 9-11).")


if __name__ == "__main__":
    main()
