"""Quickstart: FISH vs all baseline groupings through the topology API.

Reproduces the paper's headline in one minute on CPU: FISH gets Shuffle-level
load balance at Field-Grouping-level memory.  Each scheme is a typed config
on the edge of a one-stage :class:`~repro.topology.Topology`, run by the
DSPE :class:`~repro.topology.SimulatorEngine`; the same ``Topology`` object
would run unchanged on the serving engine (``ServingTopologyEngine``).

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.data.synthetic import zipf_time_evolving
from repro.topology import (Edge, SimulatorEngine, Source, Stage, Topology,
                            config_for)


def main() -> None:
    workers = 32
    keys = zipf_time_evolving(40_000, num_keys=4_000, z=1.4, seed=0)
    source = Source(keys, arrival_rate=20_000.0)
    engine = SimulatorEngine()

    print(f"{'scheme':8s} {'exec(s)':>9s} {'p99 lat(ms)':>12s} "
          f"{'mem (vs FG)':>12s} {'imbalance':>10s}")
    base_exec = None
    for scheme in ("sg", "fg", "pkg", "dc", "wc", "fish"):
        topo = Topology(
            name=f"quickstart-{scheme}",
            stages=(Stage("worker", parallelism=workers),),
            edges=(Edge("source", "worker", config_for(scheme)),),
        )
        m = engine.run(topo, source).edge("worker")
        if scheme == "sg":
            base_exec = m.execution_time
        print(f"{scheme:8s} {m.execution_time:9.3f} "
              f"{m.latency_p99 * 1e3:12.2f} {m.memory_overhead_norm:12.2f} "
              f"{m.imbalance:10.3f}")
    print("\nFISH should sit within ~1.3x of SG's execution time while "
          "holding memory within a few x of FG (paper Figs. 9-11).")


if __name__ == "__main__":
    main()
