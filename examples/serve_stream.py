"""Serve a small model with batched requests through the FISH router.

Drives real ``decode_step`` calls on model replicas under a time-evolving
session workload, then kills a replica mid-flight to show consistent-hash
failover.

    PYTHONPATH=src python examples/serve_stream.py
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.launch.serve import ModelReplica
from repro.models import transformer as T
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    replicas = [ModelReplica(cfg, params, num_slots=4, max_seq=128)
                for _ in range(3)]

    eng = ServingEngine(
        num_replicas=3, slots_per_replica=4, grouping="fish",
        step_fn=lambda r, slots: replicas[r].step(),
    )
    rng = np.random.default_rng(0)
    n = 60
    for i in range(n):
        hot = f"h{(0 if i < n // 2 else 10) + rng.integers(0, 3)}"
        sess = hot if rng.random() < 0.7 else f"c{rng.integers(0, 40)}"
        eng.submit(Request(i, sess, arrival=float(i) * 0.3,
                           target_tokens=int(rng.integers(4, 10))))

    for _ in range(8):
        eng.tick()
    moved = eng.fail_replica(2)
    print(f"replica 2 failed; {moved} requests rerouted via consistent hash")
    eng.run(until_done=n)
    m = eng.metrics()
    toks = sum(r.tokens_generated for r in replicas)
    print(f"done: {len(eng.done)}/{n} requests | p50={m.latency_p50:.0f} "
          f"p99={m.latency_p99:.0f} ticks | session replication "
          f"{m.session_replicas_norm:.2f}x | {toks} real decode tokens")


if __name__ == "__main__":
    main()
