"""End-to-end driver: train a reduced MoE model (deepseek-v2-lite family)
with FISH expert routing for a few hundred steps, through the full stack —
FISH-grouped data pipeline, AdamW, checkpointing, straggler feedback.

Compares routing modes on the way: fg (key-affine argmax) vs fish.

    PYTHONPATH=src python examples/train_moe_fish.py --steps 200
"""

import argparse
import dataclasses

from repro.configs import get_config, reduced_config
from repro.launch.train import TrainLoop
from repro.optim.adamw import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/fish_moe_ckpt")
    ap.add_argument("--routing", default="fish", choices=("fg", "pkg", "fish"))
    args = ap.parse_args()

    cfg = reduced_config(get_config("deepseek-v2-lite-16b"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, routing=args.routing),
        grad_accum=1,
    )
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20,
                          total_steps=max(args.steps, 100))
    loop = TrainLoop(cfg, opt_cfg, batch=args.batch, seq=args.seq,
                     ckpt_dir=args.ckpt_dir)
    if loop.maybe_restore():
        print(f"resumed from checkpoint at step {loop.step}")
    hist = loop.run(args.steps, ckpt_every=100, log_every=20)
    print(f"\nrouting={args.routing}: loss {hist[0]:.3f} -> {hist[-1]:.3f} "
          f"over {len(hist)} steps")
    import numpy as np
    hot = np.asarray(loop.hotness)
    frac = hot / hot.sum(axis=-1, keepdims=True)
    print(f"expert hotness (layer 0): top={frac[0].max():.3f} "
          f"min={frac[0].min():.4f} — FISH capacities follow this profile")
    loop.save()


if __name__ == "__main__":
    main()
